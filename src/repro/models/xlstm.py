"""xLSTM blocks: chunkwise-parallel mLSTM and sequential sLSTM.

TPU adaptation: the paper's fused CUDA recurrence becomes
  * mLSTM — a *chunkwise* formulation (exactly equivalent to the stabilized
    recurrence): ``lax.scan`` over chunks carrying (C, n, m); within a chunk
    the interaction is a small matmul against cumulative log-forget weights,
    which maps onto the MXU. Chunk length is a VMEM-driven knob.
  * sLSTM — has a true sequential dependency through the recurrent kernel
    R·h_{t-1}; implemented as ``lax.scan`` over time (an HLO while-loop).

Both use the exp-gate max-stabilizer `m` from the paper (App. A).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.models.layers import dense_init, rmsnorm, rmsnorm_init


# ------------------------------------------------------------------ mLSTM ----

def mlstm_init(rng, cfg: ModelConfig, dtype=jnp.bfloat16):
    d = cfg.d_model
    pf = cfg.xlstm.proj_factor
    d_in = int(d * pf / 2) * 2                 # up-proj splits in two halves
    dh = d_in // 2
    H = cfg.n_heads
    hd = dh // H
    ks = jax.random.split(rng, 8)
    return {
        "up": dense_init(ks[0], (d, d_in), dtype=dtype),
        "wq": dense_init(ks[1], (dh, dh), dtype=dtype),
        "wk": dense_init(ks[2], (dh, dh), dtype=dtype),
        "wv": dense_init(ks[3], (dh, dh), dtype=dtype),
        "w_if": dense_init(ks[4], (dh, 2 * H), scale=0.1, dtype=jnp.float32),
        "b_i": jnp.zeros((H,), jnp.float32),
        "b_f": jnp.zeros((H,), jnp.float32) + 3.0,   # open forget gates at init
        "norm": rmsnorm_init(dh, dtype),
        "down": dense_init(ks[5], (dh, d), dtype=dtype),
    }


def _mlstm_chunk(carry, qkvif, scale):
    """One chunk of the stabilized mLSTM recurrence.

    carry: (C (B,H,hd,hd), n (B,H,hd), m (B,H)) — all float32.
    qkvif: q,k,v (B,H,L,hd) float32; i_pre,f_pre (B,H,L) float32.

    The numerator (q against the carried C plus the intra-chunk (L,L)
    interaction) is chunk-parallel — that is the MXU-heavy part. The
    *state trajectory* (n_t, m_t) and the carries are stepped with the
    exact operation order of the sequential oracle (kernels/ref.mlstm_ref):
    the output divides by max(|n_t.q_t|, exp(-m_t)), a catastrophically
    cancelled dot, so any chunkwise reassociation of n_t is amplified
    without bound at near-zero denominators. The per-step scan is cheap
    ((B,H,hd) elementwise) and keeps chunk seams bit-identical to the
    sequential recurrence.
    """
    C, n, m = carry
    q, k, v, i_pre, f_pre = qkvif
    L = q.shape[2]
    logf = jax.nn.log_sigmoid(f_pre)                        # (B,H,L)
    F = jnp.cumsum(logf, axis=-1)                           # F_t = sum_{s<=t}

    ks = k * scale

    def state_step(st, inp):
        # mirrors mlstm_ref's per-step ops exactly (same rounding)
        C_s, n_s, m_s = st
        ks_t, v_t, i_t, logf_t = inp
        m_new = jnp.maximum(logf_t + m_s, i_t)
        fw = jnp.exp(logf_t + m_s - m_new)[..., None]
        iw = jnp.exp(i_t - m_new)[..., None]
        C_s = (C_s * fw[..., None]
               + iw[..., None] * (ks_t[..., :, None] * v_t[..., None, :]))
        n_s = n_s * fw + iw * ks_t
        return (C_s, n_s, m_new), (n_s, m_new)

    sw = lambda t: jnp.moveaxis(t, 2, 0)                    # time-leading
    (C_new, n_new, m_end), (n_traj, m_traj) = jax.lax.scan(
        state_step, (C, n, m), (sw(ks), sw(v), sw(i_pre), sw(logf)))
    n_t = jnp.moveaxis(n_traj, 0, 2)                        # (B,H,L,hd)
    m_t = jnp.moveaxis(m_traj, 0, -1)                       # (B,H,L)

    # numerator, chunk-parallel
    # decay(t,s) = F_t - F_s + i_s  for s <= t
    dec = F[..., :, None] - F[..., None, :] + i_pre[..., None, :]
    tri = jnp.tril(jnp.ones((L, L), bool))
    dec = jnp.where(tri, dec, -jnp.inf)
    w_inter = jnp.exp(F + m[..., None] - m_t)               # (B,H,L)
    h_inter = jnp.einsum("bhld,bhde->bhle", q, C) * w_inter[..., None]
    w_intra = jnp.exp(dec - m_t[..., None])                 # (B,H,L,L)
    logits = jnp.einsum("bhld,bhsd->bhls", q, k) * scale
    h_intra = jnp.einsum("bhls,bhls,bhsd->bhld", logits, w_intra, v)
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhld,bhld->bhl", n_t, q)),
                        jnp.exp(-m_t))
    h = (h_inter + h_intra) / denom[..., None]
    return (C_new, n_new, m_end), h


def mlstm_seq(p, x_in, cfg: ModelConfig, state, backend=None):
    """x_in: (B,S,dh) inner activations -> (y (B,S,dh), new_state).

    backend: kernel backend — a non-reference backend (without an active
    mesh) runs the VMEM-resident Pallas mlstm_scan kernel instead of the
    chunkwise lax.scan below (identical recurrence; the kernel mirrors
    the sequential oracle step-for-step)."""
    from repro.kernels import backend as KB
    B, S, dh = x_in.shape
    H = cfg.n_heads
    hd = dh // H
    L = min(cfg.xlstm.chunk_size, S)
    scale = 1.0 / math.sqrt(hd)
    to_heads = lambda t: t.reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    q = to_heads(x_in @ p["wq"]).astype(jnp.float32)
    k = to_heads(x_in @ p["wk"]).astype(jnp.float32)
    v = to_heads(x_in @ p["wv"]).astype(jnp.float32)
    gif = (x_in.astype(jnp.float32) @ p["w_if"]).reshape(B, S, 2, H)
    i_pre = gif[:, :, 0].transpose(0, 2, 1) + p["b_i"][None, :, None]
    f_pre = gif[:, :, 1].transpose(0, 2, 1) + p["b_f"][None, :, None]

    be = KB.get_backend(backend)
    if be.name != "reference" and KB.mesh_local():
        h, carry = be.mlstm_scan(q, k, v, i_pre, f_pre, state, scale=scale)
        y = h.transpose(0, 2, 1, 3).reshape(B, S, dh).astype(x_in.dtype)
        return y, carry

    carry = state
    if S <= L:
        carry, h = _mlstm_chunk(carry, (q, k, v, i_pre, f_pre), scale)
    else:
        pad = (-S) % L
        if pad:
            # pad with identity steps: no input (i=-inf), full retention
            # (f_pre large => log_sigmoid ~ 0); outputs at padded positions
            # are discarded below.
            zpad = lambda t: jnp.pad(t, ((0, 0), (0, 0), (0, pad), (0, 0)))
            gpad = lambda t, val: jnp.pad(t, ((0, 0), (0, 0), (0, pad)),
                                          constant_values=val)
            q, k, v = zpad(q), zpad(k), zpad(v)
            i_pre = gpad(i_pre, -1e30)
            f_pre = gpad(f_pre, 30.0)
        S_pad = S + ((-S) % L)
        nc = S_pad // L
        ch = lambda t: jnp.moveaxis(
            t.reshape(*t.shape[:2], nc, L, *t.shape[3:]), 2, 0)
        xs = (ch(q), ch(k), ch(v), ch(i_pre), ch(f_pre))
        carry, hs = jax.lax.scan(
            lambda c, xi: _mlstm_chunk(c, xi, scale), carry, xs)
        h = jnp.moveaxis(hs, 0, 2).reshape(B, H, -1, hd)[:, :, :S]
    y = h.transpose(0, 2, 1, 3).reshape(B, S, dh).astype(x_in.dtype)
    return y, carry


def mlstm_state_init(cfg: ModelConfig, batch: int):
    pf = cfg.xlstm.proj_factor
    dh = int(cfg.d_model * pf / 2)
    H = cfg.n_heads
    hd = dh // H
    return (jnp.zeros((batch, H, hd, hd), jnp.float32),
            jnp.zeros((batch, H, hd), jnp.float32),
            jnp.full((batch, H), -1e30, jnp.float32))


def mlstm_block(p, x, cfg: ModelConfig, state, backend=None):
    """Full mLSTM block: up-proj -> mLSTM ⊙ silu(gate) -> down-proj."""
    h = x @ p["up"]
    inner, gate = jnp.split(h, 2, axis=-1)
    y, new_state = mlstm_seq(p, inner, cfg, state, backend=backend)
    y = rmsnorm(p["norm"], y, cfg.norm_eps) * jax.nn.silu(gate)
    return y @ p["down"], new_state


# ------------------------------------------------------------------ sLSTM ----

def slstm_init(rng, cfg: ModelConfig, dtype=jnp.bfloat16):
    d = cfg.d_model
    H = cfg.n_heads
    hd = d // H
    ks = jax.random.split(rng, 4)
    d_ff = int(d * 4 / 3 / 2) * 2
    return {
        "w_gates": dense_init(ks[0], (d, 4 * d), dtype=dtype),
        "r_gates": (jax.random.normal(ks[1], (H, hd, 4 * hd), jnp.float32)
                    / math.sqrt(hd)).astype(dtype),
        "b_gates": jnp.concatenate([jnp.zeros((2 * d,), jnp.float32),
                                    jnp.full((d,), 3.0, jnp.float32),
                                    jnp.zeros((d,), jnp.float32)]),
        "w_up": dense_init(ks[2], (d, 2 * d_ff), dtype=dtype),
        "w_down": dense_init(ks[3], (d_ff, d), dtype=dtype),
        "norm_ffn": rmsnorm_init(d, dtype),
    }


def slstm_step(p, x_t, state, cfg: ModelConfig):
    """One timestep. x_t: (B,d); state: dict(c,n,h,m) each (B,H,hd) fp32."""
    B, d = x_t.shape
    H = cfg.n_heads
    hd = d // H
    c, n, h_prev, m = state["c"], state["n"], state["h"], state["m"]
    wx = (x_t @ p["w_gates"]).astype(jnp.float32).reshape(B, 4, H, hd)
    rh = jnp.einsum("bhd,hde->bhe",
                    h_prev.astype(p["r_gates"].dtype), p["r_gates"])
    rh = rh.astype(jnp.float32).reshape(B, H, 4, hd).transpose(0, 2, 1, 3)
    pre = wx + rh + p["b_gates"].reshape(4, H, hd)[None]
    z_pre, i_pre, f_pre, o_pre = pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3]
    z = jnp.tanh(z_pre)
    o = jax.nn.sigmoid(o_pre)
    logf = jax.nn.log_sigmoid(f_pre)
    m_t = jnp.maximum(logf + m, i_pre)
    fw = jnp.exp(logf + m - m_t)
    iw = jnp.exp(i_pre - m_t)
    c_t = fw * c + iw * z
    n_t = fw * n + iw
    h_t = o * c_t / jnp.maximum(n_t, 1e-6)
    return h_t, {"c": c_t, "n": n_t, "h": h_t, "m": m_t}


def slstm_seq(p, x, cfg: ModelConfig, state):
    """x: (B,S,d). Sequential scan over time."""
    B, S, d = x.shape
    H = cfg.n_heads
    hd = d // H

    def body(st, x_t):
        h_t, st = slstm_step(p, x_t, st, cfg)
        return st, h_t

    state, hs = jax.lax.scan(body, state, x.swapaxes(0, 1))
    y = hs.swapaxes(0, 1).reshape(B, S, d).astype(x.dtype)
    return y, state


def slstm_state_init(cfg: ModelConfig, batch: int):
    H = cfg.n_heads
    hd = cfg.d_model // H
    z = lambda: jnp.zeros((batch, H, hd), jnp.float32)
    return {"c": z(), "n": z(), "h": z(),
            "m": jnp.full((batch, H, hd), -1e30, jnp.float32)}


def slstm_block(p, x, cfg: ModelConfig, state):
    """sLSTM + gated FFN sub-block (residual handled by caller for slstm
    part; FFN residual internal)."""
    y, new_state = slstm_seq(p, x, cfg, state)
    h = rmsnorm(p["norm_ffn"], x + y, cfg.norm_eps)
    up, gate = jnp.split(h @ p["w_up"], 2, axis=-1)
    ffn = (jax.nn.silu(gate) * up) @ p["w_down"]
    return y + ffn, new_state
