"""Per-layer-kind block init / apply / cache-spec.

A *block* is one transformer layer of a given kind (see config.py for the
kind vocabulary). ``block_apply`` is pure and mode-polymorphic:

  mode="train"   full-sequence forward, no cache
  mode="prefill" full-sequence forward, returns a filled KV/state cache
  mode="decode"  single-token forward against a pre-allocated cache
  mode="extend"  multi-token continuation against a pre-filled cache
                 (chunked prefill: writes S new K/V entries at
                 [pos, pos+S) and attends with q_offset=pos; full
                 attention + recurrent-state kinds only — the
                 sliding-window ring buffer has no multi-token write)
  mode="verify"  batched multi-token speculative verify: scores W = K+1
                 positions per slot against a continuous-batching cache
                 with per-slot (B,) fill levels — writes W rows at
                 [pos_b, pos_b+W) per slot and attends causally at
                 per-slot offsets; pure-attention kinds only, dense or
                 paged storage. Rows past a slot's logical capacity are
                 dropped, so rejected-token rollback is a host-side
                 pos truncation (DESIGN.md §Speculative decoding)

Caches are dicts of arrays sized by ``cache_len`` (full-attention kinds) or
``cfg.window`` (sliding-window kinds — ring buffers indexed by pos % W).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig, WINDOW_KINDS
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.models import xlstm as X


# ------------------------------------------------------------------- init ----

def block_init(rng, kind: str, cfg: ModelConfig, dtype=jnp.bfloat16):
    ks = jax.random.split(rng, 8)
    d = cfg.d_model
    if kind == "mlstm":
        return {"norm1": L.rmsnorm_init(d, dtype),
                "mlstm": X.mlstm_init(ks[0], cfg, dtype)}
    if kind == "slstm":
        return {"norm1": L.rmsnorm_init(d, dtype),
                "slstm": X.slstm_init(ks[0], cfg, dtype)}
    p = {"norm1": L.rmsnorm_init(d, dtype),
         "attn": L.attn_init(ks[0], cfg, dtype=dtype),
         "norm2": L.rmsnorm_init(d, dtype)}
    if kind in ("full", "local", "enc"):
        p["mlp"] = L.mlp_init(ks[1], d, cfg.d_ff, cfg.mlp_act, dtype)
    elif kind == "dense":
        dff = cfg.d_ff if cfg.moe is None else cfg.moe.top_k * cfg.moe.d_expert
        p["mlp"] = L.mlp_init(ks[1], d, dff, cfg.mlp_act, dtype)
    elif kind == "moe":
        p["moe"] = M.moe_init(ks[1], cfg, dtype)
    elif kind in ("hymba_g", "hymba_w"):
        p["ssm"] = S.ssm_init(ks[2], cfg, dtype)
        p["norm_a"] = L.rmsnorm_init(d, dtype)
        p["norm_s"] = L.rmsnorm_init(d, dtype)
        p["mlp"] = L.mlp_init(ks[1], d, cfg.d_ff, cfg.mlp_act, dtype)
    elif kind == "encdec":
        p["norm_x"] = L.rmsnorm_init(d, dtype)
        p["xattn"] = L.attn_init(ks[3], cfg, dtype=dtype)
        p["mlp"] = L.mlp_init(ks[1], d, cfg.d_ff, cfg.mlp_act, dtype)
    else:
        raise ValueError(kind)
    return p


# ------------------------------------------------------------ cache specs ----

def block_cache_init(kind: str, cfg: ModelConfig, batch: int, cache_len: int,
                     enc_len: int = 0):
    """Zero-initialized cache for one block."""
    hd, Hkv = cfg.d_head, cfg.n_kv_heads
    if kind == "mlstm":
        return {"mlstm": X.mlstm_state_init(cfg, batch)}
    if kind == "slstm":
        return {"slstm": X.slstm_state_init(cfg, batch)}
    Sc = min(cfg.window, cache_len) if kind in WINDOW_KINDS else cache_len
    c = {"k": jnp.zeros((batch, Hkv, Sc, hd), jnp.bfloat16),
         "v": jnp.zeros((batch, Hkv, Sc, hd), jnp.bfloat16)}
    if kind in ("hymba_g", "hymba_w"):
        c["ssm"] = S.ssm_init_state(cfg, batch)
    if kind == "encdec":
        c["ck"] = jnp.zeros((batch, Hkv, enc_len, hd), jnp.bfloat16)
        c["cv"] = jnp.zeros((batch, Hkv, enc_len, hd), jnp.bfloat16)
    return c


def block_paged_cache_init(kind: str, cfg: ModelConfig, n_blocks: int,
                           block_size: int):
    """Zero-initialized paged page pool for one block. Paged caching
    covers pure-attention kinds only — recurrent state (SSM/xLSTM),
    ring buffers and cross-attention have no block-table layout."""
    if kind not in ("full", "dense", "moe"):
        raise NotImplementedError(
            f"paged KV cache over {kind!r} layers (pure-attention "
            f"stacks only)")
    hd, Hkv = cfg.d_head, cfg.n_kv_heads
    return {"k": jnp.zeros((n_blocks, Hkv, block_size, hd), jnp.bfloat16),
            "v": jnp.zeros((n_blocks, Hkv, block_size, hd), jnp.bfloat16)}


def _ring_from_prefill(k, W: int, Sc: int):
    """Pack the last W entries of k (B,H,S,hd) into ring order, padded to Sc."""
    B, H, S, hd = k.shape
    if S <= Sc:
        return jnp.pad(k, ((0, 0), (0, 0), (0, Sc - S), (0, 0)))
    last = k[:, :, -Sc:]
    return jnp.roll(last, S % Sc, axis=2)


# ------------------------------------------------------------------ apply ----

def _paged_decode(ap, q, k, v, cfg, cache, pos, block_tab, backend=None):
    """Single-token decode against a paged KV pool.

    Cache leaves are physical block pools (n_blocks, Hkv, bs, hd) shared
    by every sequence; ``block_tab`` (B, mb) names each sequence's
    logical blocks (entries >= n_blocks are out-of-table sentinels:
    their reads clamp to a resident block and are masked by kv_len,
    their writes are dropped — an idle slot touches nothing). The write
    lands in block ``block_tab[b, pos // bs]`` at offset ``pos % bs``
    with the same fp32 one-hot blend as the dense decode write, and the
    reference read is the dense attention over the gathered
    (B, Hkv, mb*bs, hd) logical view — so paged and dense decode are
    bitwise identical. A non-reference backend reads the scattered
    blocks directly via the block-table-prefetching paged flash-decode
    kernel instead of materializing the gather."""
    from repro.kernels import backend as KB
    from repro.kernels.ref import paged_gather_kv
    nb, Hkv, bs, hd = cache["k"].shape
    mb = block_tab.shape[1]
    bidx = jnp.take_along_axis(block_tab, (pos // bs)[:, None],
                               axis=1)[:, 0]                       # (B,)
    oh = jax.nn.one_hot(pos % bs, bs, dtype=jnp.float32)[:, None, :, None]
    safe = jnp.clip(bidx, 0, nb - 1)
    blk_k = jnp.take(cache["k"], safe, axis=0)         # (B, Hkv, bs, hd)
    blk_v = jnp.take(cache["v"], safe, axis=0)
    new_k = (blk_k * (1 - oh) + k.astype(jnp.float32) * oh
             ).astype(jnp.bfloat16)
    new_v = (blk_v * (1 - oh) + v.astype(jnp.float32) * oh
             ).astype(jnp.bfloat16)
    nk = cache["k"].at[bidx].set(new_k, mode="drop")
    nv = cache["v"].at[bidx].set(new_v, mode="drop")
    kv_len = jnp.minimum(pos + 1, mb * bs)
    be = KB.get_backend(backend)
    if be.name != "reference" and KB.mesh_local():
        out = be.paged_decode_attention(
            q[:, :, 0], nk, nv, block_tab, kv_len,
            cap=cfg.attn_softcap, scale=cfg.attn_scale)[:, :, None]
    else:
        out = L.attention(q, paged_gather_kv(nk, block_tab),
                          paged_gather_kv(nv, block_tab), causal=False,
                          kv_len=kv_len, cap=cfg.attn_softcap,
                          scale=cfg.attn_scale, backend=backend)
    return L.out_proj(ap, out), {"k": nk, "v": nv}


def _paged_verify(ap, q, k, v, cfg, cache, pos, block_tab, backend=None):
    """Multi-token speculative verify against a paged KV pool.

    q/k/v: (B, H, W, hd) — W = K+1 verify rows per slot at per-slot
    positions [pos_b, pos_b+W). Each row lands in block
    ``block_tab[b, (pos_b+i) // bs]`` with the same fp32 one-hot blend
    as ``_paged_decode`` (sequential over the W rows, so consecutive
    rows of one slot compose through the same block exactly as W decode
    steps would); rows at or past the logical view (or of slots with
    sentinel tables) are dropped. The reference read is dense verify
    attention over the gathered logical view; a non-reference backend
    reads the scattered blocks directly via the block-table-prefetching
    flash_verify_paged kernel."""
    from repro.kernels import backend as KB
    from repro.kernels.ref import paged_gather_kv
    nb, Hkv, bs, hd = cache["k"].shape
    mb = block_tab.shape[1]
    W = k.shape[2]
    nk, nv = cache["k"], cache["v"]
    for i in range(W):                       # W is a static python int
        p_i = pos + i                                              # (B,)
        j = jnp.minimum(p_i // bs, mb - 1)
        bidx = jnp.take_along_axis(block_tab, j[:, None], axis=1)[:, 0]
        # rows past the logical capacity write nowhere (sentinel drop)
        bidx = jnp.where(p_i < mb * bs, bidx, nb)
        oh = jax.nn.one_hot(p_i % bs, bs,
                            dtype=jnp.float32)[:, None, :, None]
        safe = jnp.clip(bidx, 0, nb - 1)
        blk_k = jnp.take(nk, safe, axis=0)             # (B, Hkv, bs, hd)
        blk_v = jnp.take(nv, safe, axis=0)
        row_k = k[:, :, i:i + 1].astype(jnp.float32)
        row_v = v[:, :, i:i + 1].astype(jnp.float32)
        new_k = (blk_k * (1 - oh) + row_k * oh).astype(jnp.bfloat16)
        new_v = (blk_v * (1 - oh) + row_v * oh).astype(jnp.bfloat16)
        nk = nk.at[bidx].set(new_k, mode="drop")
        nv = nv.at[bidx].set(new_v, mode="drop")
    be = KB.get_backend(backend)
    if be.name != "reference" and KB.mesh_local():
        out = be.paged_verify_attention(
            q, nk, nv, block_tab, pos + W,
            cap=cfg.attn_softcap, scale=cfg.attn_scale)
    else:
        out = _verify_rows(q, paged_gather_kv(nk, block_tab),
                           paged_gather_kv(nv, block_tab), cfg, pos,
                           backend=backend)
    return L.out_proj(ap, out), {"k": nk, "v": nv}


def _verify_rows(q, nk, nv, cfg, pos, backend=None):
    """Reference verify read: W per-row decode-shaped attention calls
    (row r attends kv_len = pos + r + 1, causal=False — EXACTLY the
    call a single-token decode at that position makes). One fused
    W-row attention would be mathematically identical but not bitwise:
    the score einsum's reduction order is shape-sensitive on the q
    axis, and the engine's parity contract is bitwise. W = K+1 is
    small, so the W calls cost little; the fused read lives in the
    flash_verify kernels for non-reference backends."""
    W = q.shape[2]
    outs = [L.attention(q[:, :, r:r + 1], nk, nv, causal=False,
                        kv_len=pos + r + 1, cap=cfg.attn_softcap,
                        scale=cfg.attn_scale, backend=backend)
            for r in range(W)]
    return jnp.concatenate(outs, axis=2)


def _attn_sublayer(p, x, cfg, kind, mode, cache, pos, positions, cross=False,
                   memory=None, backend=None, block_tab=None):
    """Shared attention sub-layer. Returns (y, new_cache_kv)."""
    window = cfg.window if (kind in WINDOW_KINDS and not cross) else 0
    causal = (kind != "enc") and not cross
    ap = p["xattn"] if cross else p["attn"]

    if cross:
        if mode == "decode":
            k, v = cache["ck"], cache["cv"]
            new_kv = {}
        else:
            _, k, v = L.qkv_proj(ap, memory, cfg)
            new_kv = {"ck": k.astype(jnp.bfloat16), "cv": v.astype(jnp.bfloat16)}
        B, Sq = x.shape[0], x.shape[1]
        q = (x @ ap["wq"])
        if cfg.qkv_bias:
            q = q + ap["bq"]
        q = q.reshape(B, Sq, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)
        out = L.attention(q, k, v, causal=False, cap=cfg.attn_softcap,
                          scale=cfg.attn_scale, backend=backend)
        return L.out_proj(ap, out), new_kv

    q, k, v = L.qkv_proj(ap, x, cfg)
    if cfg.rope_kind == "rope" and kind != "enc":
        q = L.apply_rope(q, positions[:, None], cfg.rope_theta)
        k = L.apply_rope(k, positions[:, None], cfg.rope_theta)
    elif cfg.rope_kind == "mrope":
        # positions: (3, B, S) -> broadcast over heads
        p3 = positions[:, :, None]                      # (3,B,1,S)
        q = L.apply_mrope(q, p3, cfg.rope_theta, cfg.mrope_sections)
        k = L.apply_mrope(k, p3, cfg.rope_theta, cfg.mrope_sections)

    if mode == "train":
        out = L.attention(q, k, v, causal=causal, window=window,
                          cap=cfg.attn_softcap, scale=cfg.attn_scale,
                          backend=backend)
        return L.out_proj(ap, out), {}

    if mode == "prefill":
        out = L.attention(q, k, v, causal=causal, window=window,
                          cap=cfg.attn_softcap, scale=cfg.attn_scale,
                          backend=backend)
        Sc = cache["k"].shape[2]
        if window:
            nk = _ring_from_prefill(k.astype(jnp.bfloat16), window, Sc)
            nv = _ring_from_prefill(v.astype(jnp.bfloat16), window, Sc)
        else:
            S = k.shape[2]
            padlen = Sc - S
            padk = lambda t: (jnp.pad(t, ((0, 0), (0, 0), (0, padlen), (0, 0)))
                              if padlen > 0 else t[:, :, :Sc])
            nk, nv = padk(k.astype(jnp.bfloat16)), padk(v.astype(jnp.bfloat16))
        return L.out_proj(ap, out), {"k": nk, "v": nv}

    if mode == "extend":
        # chunked-prefill continuation: write the S new K/V rows at
        # [pos, pos+S) of the cache (scalar pos), attend causally over
        # the filled cache with absolute query positions.
        if window:
            raise NotImplementedError(
                "extend over sliding-window ring buffers; decode "
                "token-by-token instead")
        nk = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(jnp.bfloat16), (0, 0, pos, 0))
        nv = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(jnp.bfloat16), (0, 0, pos, 0))
        out = L.attention(q, nk, nv, causal=True, q_offset=pos,
                          cap=cfg.attn_softcap, scale=cfg.attn_scale,
                          backend=backend)
        return L.out_proj(ap, out), {"k": nk, "v": nv}

    if mode == "verify":
        # speculative verify: W rows per slot at per-slot (B,) fill
        # levels. Writes are one-hot blends at rows [pos_b, pos_b+W)
        # (out-of-range rows one-hot to zeros and drop — host-side pos
        # truncation then IS the rejected-token rollback); reads use
        # per-slot kv_len = pos + r + 1 per row, so query row r keeps
        # its true position pos_b + r even when the window overhangs
        # the cache end (the engine never emits tokens from overhanging
        # rows).
        if window:
            raise NotImplementedError(
                "verify over sliding-window ring buffers")
        if block_tab is not None:
            return _paged_verify(ap, q, k, v, cfg, cache, pos, block_tab,
                                 backend=backend)
        from repro.kernels import backend as KB
        Sc = cache["k"].shape[2]
        W = k.shape[2]
        rows = pos[:, None] + jnp.arange(W)[None, :]            # (B, W)
        oh = jax.nn.one_hot(rows, Sc, dtype=jnp.float32)        # (B,W,Sc)
        written = jnp.sum(oh, axis=1)[:, None, :, None]         # (B,1,Sc,1)

        def scatter(cache_leaf, new):
            upd = jnp.einsum("bws,bhwd->bhsd", oh,
                             new.astype(jnp.float32))
            return (cache_leaf * (1.0 - written) + upd
                    ).astype(jnp.bfloat16)

        nk = scatter(cache["k"], k)
        nv = scatter(cache["v"], v)
        be = KB.get_backend(backend)
        if be.name != "reference" and KB.mesh_local():
            out = be.verify_attention(q, nk, nv, pos + W,
                                      cap=cfg.attn_softcap,
                                      scale=cfg.attn_scale)
        else:
            out = _verify_rows(q, nk, nv, cfg, pos, backend=backend)
        return L.out_proj(ap, out), {"k": nk, "v": nv}

    # decode: x is (B,1,d); write k/v at slot, attend over valid entries.
    # pos may be a scalar (synchronized batch — dynamic_update_slice) or a
    # (B,) vector (continuous batching — one-hot masked write). With a
    # block table, the cache leaves are paged pools instead of dense
    # per-slot rows (continuous batching over shared physical blocks).
    if block_tab is not None:
        return _paged_decode(ap, q, k, v, cfg, cache, pos, block_tab,
                             backend=backend)
    Sc = cache["k"].shape[2]
    if jnp.ndim(pos) == 0:
        slot = (pos % Sc) if window else jnp.minimum(pos, Sc - 1)
        nk = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(jnp.bfloat16), (0, 0, slot, 0))
        nv = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(jnp.bfloat16), (0, 0, slot, 0))
    else:
        slot = (pos % Sc) if window else jnp.minimum(pos, Sc - 1)
        oh = jax.nn.one_hot(slot, Sc, dtype=jnp.float32)[:, None, :, None]
        nk = (cache["k"] * (1 - oh) + k.astype(jnp.float32) * oh
              ).astype(jnp.bfloat16)
        nv = (cache["v"] * (1 - oh) + v.astype(jnp.float32) * oh
              ).astype(jnp.bfloat16)
    kv_len = jnp.minimum(pos + 1, Sc)
    out = L.attention(q, nk, nv, causal=False, kv_len=kv_len,
                      cap=cfg.attn_softcap, scale=cfg.attn_scale,
                      backend=backend)
    return L.out_proj(ap, out), {"k": nk, "v": nv}


def block_apply(kind: str, p, x, cfg: ModelConfig, *, mode: str,
                cache=None, pos=None, positions=None, memory=None,
                backend=None, block_tab=None):
    """Returns (x_out, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache = {}

    if kind == "mlstm":
        state = (cache or {"mlstm": X.mlstm_state_init(cfg, x.shape[0])})["mlstm"]
        y, ns = X.mlstm_block(p["mlstm"], L.rmsnorm(p["norm1"], x, cfg.norm_eps),
                              cfg, state, backend=backend)
        return x + y, {"mlstm": ns}, aux

    if kind == "slstm":
        state = (cache or {"slstm": X.slstm_state_init(cfg, x.shape[0])})["slstm"]
        y, ns = X.slstm_block(p["slstm"], L.rmsnorm(p["norm1"], x, cfg.norm_eps),
                              cfg, state)
        return x + y, {"slstm": ns}, aux

    h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)

    if kind in ("hymba_g", "hymba_w"):
        attn_y, kv = _attn_sublayer(p, h, cfg, kind, mode, cache, pos,
                                    positions, backend=backend)
        ssm_state = cache.get("ssm") if (cache and mode != "train") else None
        if mode == "train":
            ssm_y, ns = S.ssm_forward(p["ssm"], h, cfg, None, backend=backend)
        else:
            if mode == "prefill":
                ssm_state = None
            ssm_y, ns = S.ssm_forward(p["ssm"], h, cfg, ssm_state,
                                      backend=backend)
        y = 0.5 * (L.rmsnorm(p["norm_a"], attn_y, cfg.norm_eps)
                   + L.rmsnorm(p["norm_s"], ssm_y, cfg.norm_eps))
        x = x + y
        h2 = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
        x = x + L.mlp(p["mlp"], h2, cfg.mlp_act)
        new_cache = dict(kv)
        if mode != "train":
            new_cache["ssm"] = ns
        return x, new_cache, aux

    attn_y, kv = _attn_sublayer(p, h, cfg, kind, mode, cache, pos,
                                positions, backend=backend,
                                block_tab=block_tab)
    x = x + attn_y
    new_cache = dict(kv)

    if kind == "encdec":
        hx = L.rmsnorm(p["norm_x"], x, cfg.norm_eps)
        xa_y, xkv = _attn_sublayer(p, hx, cfg, kind, mode, cache, pos,
                                   positions, cross=True, memory=memory,
                                   backend=backend)
        x = x + xa_y
        new_cache.update(xkv)
        if mode == "decode":
            new_cache["ck"], new_cache["cv"] = cache["ck"], cache["cv"]

    h2 = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
    if kind == "moe":
        y, aux = M.moe_ffn(p["moe"], h2, cfg, backend=backend)
    else:
        y = L.mlp(p["mlp"], h2, cfg.mlp_act)
    return x + y, new_cache, aux
