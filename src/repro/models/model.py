"""Unified model: init / train forward / prefill / decode over segment stacks.

The layer stack is organized as *segments* ``((unit_kinds, n_repeat), ...)``;
within a segment the unit (one or more heterogeneous layers) is repeated
``n_repeat`` times and executed with ``jax.lax.scan`` over stacked
parameters, so HLO size is independent of depth. Heterogeneous stacks
(Gemma-2 local/global alternation, Hymba's sparse global layers, xLSTM's
mLSTM/sLSTM mix, Kimi's dense first layer) are expressed as either longer
units or extra segments.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig, ShapeConfig
from repro.models import layers as L
from repro.models.blocks import (block_apply, block_cache_init, block_init,
                                 block_paged_cache_init)

Params = Any
Cache = Any

# Whisper decoders are architecturally capped; decode shapes use the
# encoder axis for the long dimension.
WHISPER_DEC_CACHE = 448


# ------------------------------------------------------------------- init ----

def init_params(rng, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    rngs = jax.random.split(rng, 4 + len(cfg.segments))
    params: Dict[str, Any] = {
        "embed": L.embed_init(rngs[0], (cfg.vocab_size, cfg.d_model), dtype),
        "final_norm": L.rmsnorm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(
            rngs[1], (cfg.d_model, cfg.vocab_size), dtype=dtype)

    def stacked_segment(rng_seg, unit, R):
        keys = jax.random.split(rng_seg, R)
        seg = []
        for ui, kind in enumerate(unit):
            sub = jax.vmap(
                lambda k: block_init(jax.random.fold_in(k, ui), kind, cfg,
                                     dtype))(keys)
            seg.append(sub)
        return seg

    params["segments"] = [
        stacked_segment(rngs[4 + si], unit, R)
        for si, (unit, R) in enumerate(cfg.segments)]

    if cfg.n_enc_layers:
        enc_keys = jax.random.split(rngs[2], cfg.n_enc_layers)
        params["encoder"] = {
            "layers": jax.vmap(lambda k: block_init(k, "enc", cfg, dtype))(
                enc_keys),
            "final_norm": L.rmsnorm_init(cfg.d_model, dtype),
        }
    return params


def init_cache(cfg: ModelConfig, batch: int, cache_len: int,
               enc_len: int = 0) -> Cache:
    def stacked_cache(unit, R):
        seg = []
        for kind in unit:
            one = block_cache_init(kind, cfg, batch, cache_len, enc_len)
            seg.append(jax.tree.map(
                lambda x: jnp.broadcast_to(x, (R,) + x.shape), one))
        return seg
    return {"segments": [stacked_cache(unit, R) for unit, R in cfg.segments],
            "pos": jnp.zeros((), jnp.int32)}


def init_paged_cache(cfg: ModelConfig, batch: int, cache_len: int,
                     n_blocks: int, block_size: int) -> Cache:
    """Paged decode cache: per-layer physical block pools shared by all
    sequences plus one per-sequence block table.

    Layout per attention layer: (R, n_blocks, Hkv, block_size, hd) —
    the pool replaces the dense (R, B, Hkv, cache_len, hd) slab. The
    (B, cache_len // block_size) ``block_tab`` maps each sequence's
    logical blocks onto pool blocks; ``n_blocks`` is the sentinel for
    unmapped entries (serving/kvpool.py owns the id assignment).
    ``cache_len`` stays the per-sequence LOGICAL capacity; the physical
    budget is ``n_blocks * block_size`` rows, independent of batch.
    """
    assert cache_len % block_size == 0, (cache_len, block_size)

    def stacked_pool(unit, R):
        seg = []
        for kind in unit:
            one = block_paged_cache_init(kind, cfg, n_blocks, block_size)
            seg.append(jax.tree.map(
                lambda x: jnp.broadcast_to(x, (R,) + x.shape), one))
        return seg
    return {"segments": [stacked_pool(unit, R)
                         for unit, R in cfg.segments],
            "pos": jnp.zeros((batch,), jnp.int32),
            "block_tab": jnp.full((batch, cache_len // block_size),
                                  n_blocks, jnp.int32)}


# ------------------------------------------------------------------ stack ----

def _apply_stack(params, cfg: ModelConfig, x, *, mode, cache=None, pos=None,
                 positions=None, memory=None, remat=False, seq_axis=None,
                 backend=None, block_tab=None):
    """Run all segments. Returns (x, new_segment_caches, aux)."""
    from repro.distributed.annotate import constrain_seq
    new_segs = []
    aux_total = jnp.zeros((), jnp.float32)
    for si, (unit, R) in enumerate(cfg.segments):
        seg_params = params["segments"][si]
        seg_cache = cache["segments"][si] if cache is not None else None

        def body(h, xs, unit=unit):
            p_r = xs[0]
            c_r = xs[1] if seg_cache is not None else [None] * len(unit)
            ncs, aux = [], jnp.zeros((), jnp.float32)
            if seq_axis:   # sequence-parallel: pin the residual stream
                h = constrain_seq(h, seq_axis)
            for ui, kind in enumerate(unit):
                h, nc, a = block_apply(kind, p_r[ui], h, cfg, mode=mode,
                                       cache=c_r[ui], pos=pos,
                                       positions=positions, memory=memory,
                                       backend=backend,
                                       block_tab=block_tab)
                ncs.append(nc)
                aux = aux + a
            if seq_axis:
                h = constrain_seq(h, seq_axis)
            return h, (ncs, aux)

        if remat:
            body = jax.checkpoint(body)

        xs = (seg_params, seg_cache) if seg_cache is not None else (seg_params,)
        x, (ncs, auxs) = jax.lax.scan(lambda h, t: body(h, t), x, xs)
        new_segs.append(ncs)
        aux_total = aux_total + jnp.sum(auxs)
    return x, new_segs, aux_total


def _encode(params, cfg: ModelConfig, frames, backend=None):
    """Whisper encoder over precomputed frame embeddings (B, S_enc, d)."""
    x = frames + L.sinusoidal_positions(frames.shape[1],
                                        cfg.d_model).astype(frames.dtype)
    enc = params["encoder"]

    def body(h, p_r):
        h, _, _ = block_apply("enc", p_r, h, cfg, mode="train",
                              backend=backend)
        return h, ()

    x, _ = jax.lax.scan(body, x, enc["layers"])
    return L.rmsnorm(enc["final_norm"], x, cfg.norm_eps)


def _embed_inputs(params, cfg: ModelConfig, batch, pos=None):
    """Token (+modality) embedding. Returns (x, positions)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.emb_scale_by_sqrt_d:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if cfg.family == "vlm" and "patch_embeds" in batch:
        nv = batch["patch_embeds"].shape[1]
        x = jnp.concatenate([batch["patch_embeds"].astype(x.dtype),
                             x[:, nv:]], axis=1)
    if cfg.rope_kind == "mrope":
        positions = batch["mrope_pos"]                     # (3, B, S)
    else:
        if pos is None:
            offset = 0
        elif jnp.ndim(pos) == 0:
            offset = pos
        else:
            offset = pos[:, None]                          # (B,1) per-seq
        positions = offset + jnp.arange(S)[None, :]
        positions = jnp.broadcast_to(positions, (B, S))
    if cfg.rope_kind == "none":
        x = x + _sin_at(cfg, positions).astype(x.dtype)
    return x, positions


def _sin_at(cfg, positions):
    """Sinusoidal embedding evaluated at arbitrary positions (B,S)."""
    d = cfg.d_model
    pos = positions.astype(jnp.float32)[..., None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)
    ang = pos / (10_000.0 ** (dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _logits(params, cfg: ModelConfig, x):
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = x.astype(jnp.float32) @ head.astype(jnp.float32)
    if cfg.final_softcap:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    return logits


def xent_chunked(params, cfg: ModelConfig, x, labels, chunk: int = 256):
    """Cross-entropy without materializing (B,S,V) logits: scan over S."""
    B, S, d = x.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk
    xs = x.reshape(B, nc, chunk, d).swapaxes(0, 1)
    ls = labels.reshape(B, nc, chunk).swapaxes(0, 1)

    def body(acc, inp):
        xc, lc = inp
        logits = _logits(params, cfg, xc)                  # (B,C,V) fp32
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(
            logits, jnp.maximum(lc, 0)[..., None], axis=-1)[..., 0]
        mask = (lc >= 0).astype(jnp.float32)
        loss = jnp.sum((lse - picked) * mask)
        return (acc[0] + loss, acc[1] + jnp.sum(mask)), ()

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())), (xs, ls))
    return tot / jnp.maximum(cnt, 1.0)


# ------------------------------------------------------------- public API ----

def train_loss(params, cfg: ModelConfig, batch, remat: bool = True,
               backend=None):
    """Full training forward -> scalar LM loss (+ MoE aux)."""
    if cfg.n_enc_layers:
        memory = _encode(params, cfg, batch["frames"], backend=backend)
    else:
        memory = None
    x, positions = _embed_inputs(params, cfg, batch)
    x, _, aux = _apply_stack(params, cfg, x, mode="train",
                             positions=positions, memory=memory, remat=remat,
                             backend=backend)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    loss = xent_chunked(params, cfg, x, batch["labels"])
    return loss + aux


def prefill(params, cfg: ModelConfig, batch, cache_len: int, seq_axis=None,
            backend=None):
    """Process a prompt; returns (last-token logits (B,V), filled cache).

    seq_axis: mesh axis name for sequence-parallel prefill (context
    parallelism) — the residual stream's seq dim is pinned to it.
    backend: kernel backend for the attention/router/scan hot paths.
    """
    if cfg.n_enc_layers:
        memory = _encode(params, cfg, batch["frames"], backend=backend)
        enc_len = memory.shape[1]
    else:
        memory, enc_len = None, 0
    x, positions = _embed_inputs(params, cfg, batch)
    S = batch["tokens"].shape[1]
    cache = init_cache(cfg, batch["tokens"].shape[0],
                       min(cache_len, WHISPER_DEC_CACHE)
                       if cfg.n_enc_layers else cache_len, enc_len)
    x, new_segs, _ = _apply_stack(params, cfg, x, mode="prefill",
                                  cache=cache, pos=jnp.zeros((), jnp.int32),
                                  positions=positions, memory=memory,
                                  seq_axis=seq_axis, backend=backend)
    x_last = L.rmsnorm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    logits = _logits(params, cfg, x_last)[:, 0]
    return logits, {"segments": new_segs,
                    "pos": jnp.asarray(S, jnp.int32)}


def prefill_extend(params, cfg: ModelConfig, cache, batch, n_valid=None,
                   backend=None):
    """Chunked-prefill continuation: advance a pre-filled cache through S
    new tokens in ONE pass (the engine's prompt-prefix cache uses this to
    attach per-request suffixes to a shared prefix prefill).

    batch["tokens"]: (B, S); cache carries a scalar ``pos``. ``n_valid``
    (defaults to S) supports bucket-padded calls: logits are taken at
    position n_valid-1 and ``pos`` advances by n_valid, so pad tokens
    beyond it are never attended (causal mask) and their cache rows are
    overwritten by later writes before becoming visible. Pad-extend is
    only sound for pure-attention stacks — recurrent state (SSM/xLSTM)
    would step through the pads. Sliding-window kinds raise
    NotImplementedError (no multi-token ring-buffer write); enc-dec
    stacks are unsupported.
    """
    assert not cfg.n_enc_layers, "prefill_extend: enc-dec unsupported"
    pos = cache["pos"]
    S = batch["tokens"].shape[1]
    n_valid = S if n_valid is None else n_valid
    x, positions = _embed_inputs(params, cfg, batch, pos=pos)
    x, new_segs, _ = _apply_stack(params, cfg, x, mode="extend",
                                  cache=cache, pos=pos,
                                  positions=positions, backend=backend)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    last = jax.lax.dynamic_slice_in_dim(x, n_valid - 1, 1, axis=1)
    logits = _logits(params, cfg, last)[:, 0]
    return logits, {"segments": new_segs, "pos": pos + n_valid}


def verify_extend(params, cfg: ModelConfig, cache, batch, backend=None):
    """Speculative-decode verify: score W = K+1 draft positions per slot
    in ONE forward against a continuous-batching cache with per-slot
    (B,) fill levels.

    batch["tokens"]: (B, W) — per slot, the carried last token followed
    by its K draft proposals. Returns logits for ALL W positions
    ((B, W, V) fp32 — row i is the target distribution for the token
    after batch["tokens"][:, :i+1]) plus the cache with the W KV rows
    written at [pos_b, pos_b+W). ``pos`` is returned UNCHANGED: the
    engine advances each slot by its accepted length on the host, and
    that truncation is the whole rejected-token rollback (dropped rows
    are masked in dense storage and overwritten in paged blocks before
    ever becoming visible). Works against dense and paged caches (a
    ``block_tab`` rides through like decode_step); pure-attention
    stacks only — recurrent state cannot be rolled back by truncation.
    """
    assert not cfg.n_enc_layers, "verify_extend: enc-dec unsupported"
    pos = cache["pos"]
    tab = cache.get("block_tab")
    x, positions = _embed_inputs(params, cfg, batch, pos=pos)
    x, new_segs, _ = _apply_stack(params, cfg, x, mode="verify",
                                  cache=cache, pos=pos,
                                  positions=positions, backend=backend,
                                  block_tab=tab)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    # per-row head matmuls, not one (B,W,d)@(d,V): the tied-embedding
    # head is a transposed operand whose gemm reduction order is
    # shape-sensitive on the row axis — decode emits from (B,1,d)
    # calls, and verify logits must match them BITWISE, not allclose
    W = x.shape[1]
    logits = jnp.stack([_logits(params, cfg, x[:, i:i + 1])[:, 0]
                        for i in range(W)], axis=1)         # (B, W, V)
    out = {"segments": new_segs, "pos": pos}
    if tab is not None:
        out["block_tab"] = tab
    return logits, out


def decode_step(params, cfg: ModelConfig, cache, batch, backend=None):
    """One decode step. batch["tokens"]: (B,1). Returns (logits, cache).

    A cache carrying a ``block_tab`` (init_paged_cache) decodes against
    the paged block pools; the table rides through unchanged (the engine
    owns table mutation on the host)."""
    pos = cache["pos"]
    tab = cache.get("block_tab")
    x, positions = _embed_inputs(params, cfg, batch, pos=pos)
    x, new_segs, _ = _apply_stack(params, cfg, x, mode="decode",
                                  cache=cache, pos=pos, positions=positions,
                                  backend=backend, block_tab=tab)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = _logits(params, cfg, x)[:, 0]
    out = {"segments": new_segs, "pos": pos + 1}
    if tab is not None:
        out["block_tab"] = tab
    return logits, out


# ------------------------------------------------------------ accounting ----

def param_shapes(cfg: ModelConfig):
    return jax.eval_shape(
        lambda: init_params(jax.random.PRNGKey(0), cfg))


def count_params_analytic(cfg: ModelConfig) -> int:
    shapes = param_shapes(cfg)
    return sum(int(jnp.prod(jnp.array(l.shape)))
               for l in jax.tree.leaves(shapes))


def count_active_params(cfg: ModelConfig) -> int:
    """Active parameters per token (MoE experts counted top_k/E)."""
    shapes = param_shapes(cfg)
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        n = 1
        for s in leaf.shape:
            n *= int(s)
        keys = [getattr(k, "key", getattr(k, "name", "")) for k in path
                if hasattr(k, "key") or hasattr(k, "name")]
        if cfg.moe and "moe" in keys and any(
                k in ("w_gate", "w_up", "w_down") for k in keys):
            n = n * cfg.moe.top_k // cfg.moe.n_experts
        total += n
    return total
