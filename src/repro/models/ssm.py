"""Mamba-style selective state-space layer (used by the Hymba hybrid blocks).

TPU adaptation: the CUDA selective-scan kernel becomes a *chunked
associative scan* — ``jax.lax.scan`` over sequence chunks carrying the SSM
state, with ``jax.lax.associative_scan`` inside each chunk. This bounds the
(B, chunk, d_inner, d_state) temporary to VMEM-friendly sizes while keeping
O(S) work, and it lowers to plain HLO that GSPMD can partition (d_inner on
the ``model`` axis).

Decode uses the exact single-step recurrence with a carried (h, conv) state.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.models.layers import dense_init

SCAN_CHUNK = 512


def ssm_init(rng, cfg: ModelConfig, dtype=jnp.bfloat16):
    scfg = cfg.ssm
    d = cfg.d_model
    d_inner = scfg.expand * d
    dt_rank = scfg.dt_rank or max(1, math.ceil(d / 16))
    ks = jax.random.split(rng, 8)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * d_inner), dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (scfg.d_conv, d_inner), jnp.float32)
                   / math.sqrt(scfg.d_conv)).astype(dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "w_bc": dense_init(ks[2], (d_inner, 2 * scfg.d_state), dtype=dtype),
        "w_dt": dense_init(ks[3], (d_inner, dt_rank), dtype=dtype),
        "dt_proj": dense_init(ks[4], (dt_rank, d_inner), dtype=dtype),
        "dt_bias": jnp.zeros((d_inner,), jnp.float32) - 4.6,   # softplus^-1(0.01)
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, scfg.d_state + 1, dtype=jnp.float32),
            (d_inner, scfg.d_state))),
        "D": jnp.ones((d_inner,), jnp.float32),
        "out_proj": dense_init(ks[5], (d_inner, d), dtype=dtype),
    }


def _causal_conv(x, w, b):
    """x: (B,S,di); depthwise causal conv with kernel (K,di)."""
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1], :] * w[i] for i in range(K))
    return out + b


def _ssm_params(p, x, scfg):
    """x: (B,S,di) post-conv activations -> dt (B,S,di), B_, C_ (B,S,n)."""
    bc = x @ p["w_bc"]
    B_, C_ = jnp.split(bc.astype(jnp.float32), 2, axis=-1)
    dt = jax.nn.softplus((x @ p["w_dt"]) @ p["dt_proj"]
                         + p["dt_bias"].astype(x.dtype))
    return dt.astype(jnp.float32), B_, C_


def _scan_chunk(h0, a, bx):
    """Associative scan of h_t = a_t * h_{t-1} + bx_t within a chunk.

    a, bx: (B, C, di, n); h0: (B, di, n). Returns (h_all (B,C,di,n), h_last).
    """
    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br
    a_cum, b_cum = jax.lax.associative_scan(combine, (a, bx), axis=1)
    h_all = a_cum * h0[:, None] + b_cum
    return h_all, h_all[:, -1]


def selective_scan(p, x, h0, chunk: int = 0, backend=None):
    """Selective SSM over a full sequence.

    x: (B,S,di) conv+silu activations; h0: (B,di,n) initial state.
    Returns (y (B,S,di) float32, h_last (B,di,n)).

    backend: kernel backend — a non-reference backend (without an active
    mesh) runs the blocked Pallas ssm_scan kernel, state-carried in VMEM,
    instead of the chunked associative scan below.

    Perf knobs (common.perf): chunk length bounds the (B,chunk,di,n)
    associative-scan temporaries; ssm_scan_dtype runs the intra-chunk
    elements in bf16 while the carried state stays fp32.
    """
    from repro.common.perf import get_flags
    from repro.kernels import backend as KB
    flags = get_flags()
    chunk = chunk or flags.ssm_scan_chunk
    scan_dtype = jnp.dtype(flags.ssm_scan_dtype)

    B, S, di = x.shape
    A = -jnp.exp(p["A_log"])                       # (di, n)
    n = A.shape[-1]
    dt, B_, C_ = _ssm_params(p, x, None)
    xf = x.astype(jnp.float32)

    be = KB.get_backend(backend)
    if be.name != "reference" and KB.mesh_local():
        y, h_last = be.selective_scan(dt, xf, B_, C_, A, h0)
        y = y + xf * p["D"]
        return y, h_last

    def chunk_body(h, inp):
        dt_c, B_c, C_c, x_c = inp                  # (B,C,...) chunk slices
        a = jnp.exp(dt_c[..., None] * A).astype(scan_dtype)  # (B,C,di,n)
        bx = ((dt_c * x_c)[..., None]
              * B_c[:, :, None, :]).astype(scan_dtype)
        h_all, h_last = _scan_chunk(h.astype(scan_dtype), a, bx)
        y = jnp.einsum("bcdn,bcn->bcd", h_all,
                       C_c.astype(scan_dtype)).astype(jnp.float32)
        return h_last.astype(jnp.float32), y

    if S <= chunk:
        h_last, y = chunk_body(h0, (dt, B_, C_, xf))
    else:
        pad = (-S) % chunk
        if pad:
            z = lambda t: jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
            dt, B_, C_, xf = z(dt), z(B_), z(C_), z(xf)
        nc = (S + pad) // chunk
        resh = lambda t: t.reshape(B, nc, chunk, *t.shape[2:]).swapaxes(0, 1)
        h_last, ys = jax.lax.scan(chunk_body, h0, (resh(dt), resh(B_),
                                                   resh(C_), resh(xf)))
        y = ys.swapaxes(0, 1).reshape(B, nc * chunk, di)[:, :S]
    y = y + xf[:, :y.shape[1]] * p["D"]
    return y, h_last


def ssm_forward(p, x, cfg: ModelConfig, state=None, backend=None):
    """Full mamba layer over a sequence. x: (B,S,d).

    state: None (fresh) or dict with h (B,di,n), conv (B,K-1,di).
    Returns (y (B,S,d), new_state).
    """
    scfg = cfg.ssm
    B, S, _ = x.shape
    xz = x @ p["in_proj"]
    xi, z = jnp.split(xz, 2, axis=-1)
    K = scfg.d_conv
    if state is not None:
        prev = state["conv"].astype(xi.dtype)             # (B,K-1,di)
        xi_ext = jnp.concatenate([prev, xi], axis=1)
        conv = _causal_conv(xi_ext, p["conv_w"], p["conv_b"])[:, K - 1:]
        h0 = state["h"]
    else:
        conv = _causal_conv(xi, p["conv_w"], p["conv_b"])
        di = xi.shape[-1]
        h0 = jnp.zeros((B, di, scfg.d_state), jnp.float32)
    act = jax.nn.silu(conv)
    y, h_last = selective_scan(p, act, h0, backend=backend)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    new_state = {
        "h": h_last,
        "conv": (jnp.concatenate([state["conv"].astype(xi.dtype), xi], axis=1)
                 if state is not None else
                 jnp.pad(xi, ((0, 0), (K - 1, 0), (0, 0))))[:, -(K - 1):]
        .astype(jnp.bfloat16),
    }
    return y @ p["out_proj"], new_state


def ssm_init_state(cfg: ModelConfig, batch: int):
    scfg = cfg.ssm
    di = scfg.expand * cfg.d_model
    return {"h": jnp.zeros((batch, di, scfg.d_state), jnp.float32),
            "conv": jnp.zeros((batch, scfg.d_conv - 1, di), jnp.bfloat16)}
