"""Mixture-of-Experts FFN with capacity-bounded dispatch.

Two dispatch implementations:

* ``einsum``   — GShard-style one-hot dispatch/combine einsums. This is the
  paper-era baseline: it lowers cleanly to all-to-all under GSPMD when the
  expert axis is sharded over the ``model`` mesh axis, but it spends real
  MXU flops on the one-hot matmuls (visible in cost_analysis — the roofline
  §Perf loop flips to ``gather`` to recover them).
* ``gather``   — take/segment-matmul dispatch: tokens are gathered into a
  dense (E, C, d) buffer with jnp.take and combined with a scatter-free
  weighted sum. Far fewer flops; GSPMD still partitions the expert matmuls.

Router top-k runs in fp32. The auxiliary load-balance loss follows
Switch/GShard: E * sum_e(f_e * p_e).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig, MoEConfig
from repro.models.layers import dense_init, mlp_init, mlp

DISPATCH_MODE = "einsum"   # module-level default; overridable per-call


def moe_init(rng, cfg: ModelConfig, dtype=jnp.bfloat16):
    mcfg = cfg.moe
    ks = jax.random.split(rng, 6)
    d, dff, E = cfg.d_model, mcfg.d_expert, mcfg.n_experts
    p = {
        "router": dense_init(ks[0], (d, E), scale=0.1, dtype=jnp.float32),
        # Expert FFNs stacked on a leading expert axis (sharded over `model`).
        "w_gate": (jax.random.normal(ks[1], (E, d, dff), jnp.float32)
                   / math.sqrt(d)).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (E, d, dff), jnp.float32)
                 / math.sqrt(d)).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (E, dff, d), jnp.float32)
                   / math.sqrt(dff)).astype(dtype),
    }
    if mcfg.dense_residual_ff:
        p["dense_residual"] = mlp_init(ks[4], d, mcfg.dense_residual_ff,
                                       cfg.mlp_act, dtype)
    if mcfg.shared_expert_ff:
        p["shared_expert"] = mlp_init(ks[5], d, mcfg.shared_expert_ff,
                                      cfg.mlp_act, dtype)
    return p


def capacity(mcfg: MoEConfig, n_tokens: int) -> int:
    from repro.common.perf import get_flags
    cf = get_flags().moe_capacity_factor or mcfg.capacity_factor
    c = int(math.ceil(cf * mcfg.top_k * n_tokens / mcfg.n_experts))
    return max(8, -(-c // 8) * 8)      # round up to a multiple of 8


def router_topk(router_w, x, mcfg: MoEConfig, backend=None):
    """x: (B,S,d) -> (weights (B,S,k), idx (B,S,k) int32, probs (B,S,E)).

    With a non-reference kernel backend (and no active mesh), the fused
    softmax+top-k Pallas kernel selects the experts; probs are still
    computed here — the load-balance aux loss needs the full (B,S,E)
    distribution either way."""
    from repro.kernels import backend as KB
    logits = x.astype(jnp.float32) @ router_w            # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    be = KB.get_backend(backend)
    if be.name != "reference" and KB.mesh_local():
        B, S, E = logits.shape
        w, idx = be.router_topk(logits.reshape(B * S, E), mcfg.top_k)
        return (w.reshape(B, S, mcfg.top_k),
                idx.reshape(B, S, mcfg.top_k), probs)
    w, idx = jax.lax.top_k(probs, mcfg.top_k)
    w = w / jnp.maximum(jnp.sum(w, -1, keepdims=True), 1e-9)
    return w, idx, probs


def load_balance_loss(probs, idx, mcfg: MoEConfig):
    E = mcfg.n_experts
    onehot = jax.nn.one_hot(idx[..., 0], E, dtype=jnp.float32)   # top-1 choice
    f = jnp.mean(onehot, axis=(0, 1))
    p = jnp.mean(probs, axis=(0, 1))
    return E * jnp.sum(f * p)


def _expert_ffn(p, xe, act: str):
    """xe: (E, C, d) -> (E, C, d); expert-stacked matmuls."""
    if act.endswith("_glu"):
        gate = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])
        up = jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
        h = (jax.nn.silu(gate) if act == "silu_glu"
             else jax.nn.gelu(gate, approximate=True)) * up
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", xe, p["w_up"]),
                        approximate=True)
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"])


def _dispatch_einsum(p, x, w, idx, mcfg, act, pin: bool = False):
    """GShard one-hot dispatch. x: (B,S,d).

    pin=True applies the GShard-canonical sharding constraints so the
    token exchange lowers to all-to-all over (data <-> model) instead of
    GSPMD's replicate+all-reduce fallback (see EXPERIMENTS.md §Perf,
    kimi-prefill iteration 2).
    """
    from repro.distributed.annotate import constrain
    dp = ("pod", "data")
    c9 = (lambda t, *ax: constrain(t, *ax)) if pin else (lambda t, *ax: t)
    B, S, d = x.shape
    E = mcfg.n_experts
    C = capacity(mcfg, S)
    # Position of each (token, k) inside its expert's buffer.
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.int32)             # (B,S,k,E)
    pos = jnp.cumsum(onehot.reshape(B, S * mcfg.top_k, E), axis=1) - 1
    pos = pos.reshape(B, S, mcfg.top_k, E)
    in_cap = (pos < C) & (onehot > 0)
    # dispatch (B,S,E,C) / combine (B,S,E,C)
    pos_oh = jax.nn.one_hot(pos, C, dtype=x.dtype) * in_cap[..., None]
    dispatch = jnp.einsum("bske,bskec->bsec", onehot.astype(x.dtype),
                          pos_oh * 1.0)
    combine = jnp.einsum("bsk,bske,bskec->bsec", w.astype(x.dtype),
                         onehot.astype(x.dtype), pos_oh)
    dispatch = c9(dispatch, dp, None, "model", None)
    combine = c9(combine, dp, None, "model", None)
    # Group axis = batch. expert_in: (E, B, C, d)
    expert_in = c9(jnp.einsum("bsec,bsd->ebcd", dispatch, c9(x, dp, None, None)),
                   "model", dp, None, None)
    Eb = expert_in.reshape(E, B * C, d)
    out = c9(_expert_ffn(p, Eb, act).reshape(E, B, C, d),
             "model", dp, None, None)
    y = c9(jnp.einsum("bsec,ebcd->bsd", combine, out), dp, None, None)
    return y


def _dispatch_gather(p, x, w, idx, mcfg, act, pin: bool = False):
    """Sort-free gather dispatch: flat take into (E, C, d) buffers.

    pin=True: expert buffers constrained to the `model` axis inside the
    per-batch vmap (spmd_axis_name keeps the batch dim on `data`), so the
    token exchange lowers to all-to-all instead of the combine-gather
    all-reduce (EXPERIMENTS.md §Perf kimi iteration 5).
    """
    from repro.distributed.annotate import constrain
    B, S, d = x.shape
    E, K = mcfg.n_experts, mcfg.top_k
    C = capacity(mcfg, S)

    def per_batch(xb, wb, ib):
        # xb (S,d), wb (S,K), ib (S,K)
        flat_e = ib.reshape(-1)                                   # (S*K,)
        oh = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
        pos = (jnp.cumsum(oh, axis=0) - 1)[jnp.arange(S * K), flat_e]
        keep = pos < C
        slot = jnp.where(keep, flat_e * C + pos, E * C)           # overflow slot
        tok = jnp.repeat(jnp.arange(S), K)
        # Gather tokens into expert buffers via scatter into (E*C+1, ).
        buf_tok = jnp.zeros((E * C + 1,), jnp.int32).at[slot].set(tok)
        buf_valid = jnp.zeros((E * C + 1,), jnp.bool_).at[slot].set(keep)
        xe = jnp.take(xb, buf_tok[:-1], axis=0) * buf_valid[:-1, None]
        xe = xe.reshape(E, C, d)
        if pin:
            xe = constrain(xe, "model", None, None)
        ye = _expert_ffn(p, xe, act)
        if pin:
            ye = constrain(ye, "model", None, None)
        ye = ye.reshape(E * C, d)
        # Combine: each (token,k) reads back its slot.
        contrib = jnp.take(jnp.concatenate([ye, jnp.zeros((1, d), ye.dtype)]),
                           slot, axis=0)
        contrib = contrib * (wb.reshape(-1, 1).astype(ye.dtype) * keep[:, None])
        return jnp.sum(contrib.reshape(S, K, d), axis=1)

    vm = (jax.vmap(per_batch, spmd_axis_name="data") if pin
          else jax.vmap(per_batch))
    return vm(x, w.astype(x.dtype), idx)


def _dispatch_shard_map(p, x, w, idx, mcfg, act):
    """Expert-parallel dispatch as an explicit shard_map over `model`.

    Written for the TPU production mesh after the GSPMD-only iterations
    (EXPERIMENTS.md §Perf kimi 1-5) plateaued: each model shard owns
    E/m contiguous experts, gathers its assigned tokens *locally* (x is
    replicated across `model`), runs the expert FFNs, and the combine is
    a single bf16 psum of (B,S,d) per layer — no (B,S,E,C) one-hot masks
    and no dispatch matmuls at all. Falls back to `gather` without a mesh.
    """
    from repro.distributed.annotate import _mesh
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    mesh = _mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return _dispatch_gather(p, x, w, idx, mcfg, act)
    m_size = mesh.shape["model"]
    E, K = mcfg.n_experts, mcfg.top_k
    if E % m_size != 0:
        return _dispatch_gather(p, x, w, idx, mcfg, act)
    el = E // m_size
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    B, S, d = x.shape
    bax = dp if (B % n_dp == 0 and B > 1) else ()
    bspec = bax if bax else None

    def shard_fn(p_loc, xb, wb, ib):
        j = jax.lax.axis_index("model")
        Bl, Sl, _ = xb.shape
        N = Bl * Sl
        C = capacity(mcfg, N)
        xf = xb.reshape(N, d)
        ib_loc = ib.reshape(N * K) - j * el          # local expert ids
        wf = wb.reshape(N * K)
        mine = (ib_loc >= 0) & (ib_loc < el)
        e_loc = jnp.where(mine, ib_loc, el)          # el = overflow expert
        oh = jax.nn.one_hot(e_loc, el + 1, dtype=jnp.int32)
        pos = (jnp.cumsum(oh, axis=0) - 1)[jnp.arange(N * K), e_loc]
        keep = mine & (pos < C)
        slot = jnp.where(keep, e_loc * C + pos, el * C)
        tok = jnp.repeat(jnp.arange(N), K)
        buf_tok = jnp.zeros((el * C + 1,), jnp.int32).at[slot].set(tok)
        buf_valid = jnp.zeros((el * C + 1,), jnp.bool_).at[slot].set(keep)
        xe = (jnp.take(xf, buf_tok[:-1], axis=0)
              * buf_valid[:-1, None]).reshape(el, C, d)
        ye = _expert_ffn(p_loc, xe, act).reshape(el * C, d)
        contrib = jnp.take(
            jnp.concatenate([ye, jnp.zeros((1, d), ye.dtype)]), slot, axis=0)
        contrib = contrib * (wf[:, None].astype(ye.dtype) * keep[:, None])
        y = jnp.sum(contrib.reshape(Bl, Sl, K, d), axis=2)
        return jax.lax.psum(y.astype(xb.dtype), "model")

    p_exp = {k: p[k] for k in ("w_gate", "w_up", "w_down")}
    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P("model"), p_exp),
                  P(bspec, None, None), P(bspec, None, None),
                  P(bspec, None, None)),
        out_specs=P(bspec, None, None), check_rep=False)
    return fn(p_exp, x, w, idx)


def moe_ffn(p, x, cfg: ModelConfig, dispatch: str = None, backend=None):
    """Full MoE FFN layer. Returns (y, aux_loss)."""
    from repro.common.perf import get_flags
    mcfg = cfg.moe
    mode = dispatch or get_flags().moe_dispatch
    w, idx, probs = router_topk(p["router"], x, mcfg, backend=backend)
    aux = load_balance_loss(probs, idx, mcfg) * mcfg.aux_loss_weight
    # Dispatch pins only help bulk (train/prefill) token exchange; for
    # decode (S=1) they forced per-step all-to-alls that regressed the
    # first production sweep by ~40% — let GSPMD choose there.
    pin = get_flags().moe_constraint == "auto" and x.shape[1] > 1
    if mode == "einsum":
        y = _dispatch_einsum(p, x, w, idx, mcfg, cfg.mlp_act, pin=pin)
    elif mode == "gather":
        y = _dispatch_gather(p, x, w, idx, mcfg, cfg.mlp_act, pin=pin)
    elif mode == "shard_map":
        if x.shape[1] > 1:
            y = _dispatch_shard_map(p, x, w, idx, mcfg, cfg.mlp_act)
        else:
            # decode (S=1): the broadcast+psum exchange costs more than a
            # single token's FFN — use the plain einsum path, unpinned
            y = _dispatch_einsum(p, x, w, idx, mcfg, cfg.mlp_act, pin=False)
    else:
        raise ValueError(mode)
    if "dense_residual" in p:
        y = y + mlp(p["dense_residual"], x, cfg.mlp_act)
    if "shared_expert" in p:
        y = y + mlp(p["shared_expert"], x, cfg.mlp_act)
    return y, aux
