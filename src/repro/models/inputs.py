"""Input construction for every (architecture × input shape) pair.

``input_specs`` returns jax.ShapeDtypeStruct stand-ins (no allocation) for
the dry-run; ``make_batch`` returns concrete arrays for smoke tests and
examples. Both produce the same pytree structure:

  train:   {"tokens", "labels"} (+frames | +patch_embeds/mrope_pos)
  prefill: {"tokens"} (+frames | +patch_embeds/mrope_pos)
  decode:  ({"tokens"(B,1)} (+mrope_pos), cache)

Modality stubs (the one allowed carve-out): whisper "frames" and qwen2-vl
"patch_embeds" are precomputed embeddings of the correct shape.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import ModelConfig, ShapeConfig
from repro.models.model import WHISPER_DEC_CACHE, init_cache

SDS = jax.ShapeDtypeStruct


def _whisper_dec_len(seq_len: int) -> int:
    # Decoder prompt rides along with the long encoder axis.
    return max(16, min(WHISPER_DEC_CACHE, seq_len // 128))


def batch_struct(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct batch for (cfg, shape). Decode: token batch only."""
    B, S = shape.global_batch, shape.seq_len
    if shape.mode == "decode":
        b: Dict[str, Any] = {"tokens": SDS((B, 1), jnp.int32)}
        if cfg.rope_kind == "mrope":
            b["mrope_pos"] = SDS((3, B, 1), jnp.int32)
        return b
    if cfg.family == "audio":
        Sd = _whisper_dec_len(S)
        b = {"tokens": SDS((B, Sd), jnp.int32),
             "frames": SDS((B, S, cfg.d_model), jnp.bfloat16)}
        if shape.mode == "train":
            b["labels"] = SDS((B, Sd), jnp.int32)
        return b
    b = {"tokens": SDS((B, S), jnp.int32)}
    if cfg.family == "vlm":
        b["patch_embeds"] = SDS((B, cfg.n_vision_tokens, cfg.d_model),
                                jnp.bfloat16)
        b["mrope_pos"] = SDS((3, B, S), jnp.int32)
    if shape.mode == "train":
        b["labels"] = SDS((B, S), jnp.int32)
    return b


def cache_struct(cfg: ModelConfig, shape: ShapeConfig) -> Any:
    """ShapeDtypeStruct cache for decode shapes."""
    assert shape.mode == "decode"
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "audio":
        tree = jax.eval_shape(
            lambda: init_cache(cfg, B, WHISPER_DEC_CACHE, enc_len=S))
    else:
        tree = jax.eval_shape(lambda: init_cache(cfg, B, S))
    return tree


def make_batch(cfg: ModelConfig, shape: ShapeConfig, seed: int = 0):
    """Concrete batch (numpy-backed) matching batch_struct."""
    rng = np.random.default_rng(seed)
    spec = batch_struct(cfg, shape)
    out = {}
    for k, v in spec.items():
        if v.dtype == jnp.int32:
            if k == "mrope_pos":
                # text positions: t=h=w=position index (vision handled by env)
                pos = np.broadcast_to(np.arange(v.shape[-1], dtype=np.int32),
                                      v.shape)
                out[k] = jnp.asarray(pos)
            else:
                out[k] = jnp.asarray(
                    rng.integers(0, cfg.vocab_size, v.shape, dtype=np.int32))
        else:
            out[k] = jnp.asarray(
                rng.standard_normal(v.shape, dtype=np.float32), v.dtype)
    return out


def make_decode_state(cfg: ModelConfig, shape: ShapeConfig, prefill_len: int):
    """Concrete zero cache positioned at prefill_len (smoke decode tests)."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "audio":
        cache = init_cache(cfg, B, WHISPER_DEC_CACHE, enc_len=S)
    else:
        cache = init_cache(cfg, B, S)
    cache["pos"] = jnp.asarray(prefill_len, jnp.int32)
    return cache
