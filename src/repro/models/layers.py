"""Core model primitives: norms, rotary embeddings, attention, MLPs.

All functions are pure JAX over explicit parameter pytrees (dicts of
jnp arrays) so they compose with pjit/shard_map without a framework.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig

# ------------------------------------------------------------------ init ----

def dense_init(rng, shape, scale: float = 1.0, dtype=jnp.bfloat16):
    fan_in = shape[0]
    std = scale / math.sqrt(fan_in)
    return (jax.random.normal(rng, shape, jnp.float32) * std).astype(dtype)


def embed_init(rng, shape, dtype=jnp.bfloat16):
    return (jax.random.normal(rng, shape, jnp.float32) * 0.02).astype(dtype)


# ----------------------------------------------------------------- norms ----

def rmsnorm_init(d: int, dtype=jnp.bfloat16):
    return {"scale": jnp.zeros((d,), dtype)}


def rmsnorm(params, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    normed = xf * jax.lax.rsqrt(var + eps)
    return (normed * (1.0 + params["scale"].astype(jnp.float32))).astype(x.dtype)


# ------------------------------------------------------------------ rope ----

def rope_freqs(d_head: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x, positions, theta: float):
    """x: (..., S, d_head); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (d/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., S, d/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions_3d, theta: float, sections):
    """Multimodal RoPE (Qwen2-VL).

    x: (..., S, d_head); positions_3d: (3, ..., S) with (t, h, w) ids;
    sections: per-axis counts of rotary frequency pairs, sum == d_head//2.
    """
    d = x.shape[-1]
    half = d // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(d, theta)                       # (half,)
    # Build per-frequency position: frequencies are assigned to t/h/w blocks.
    sec_id = jnp.repeat(jnp.arange(3), jnp.array(sections), total_repeat_length=half)
    pos = positions_3d.astype(jnp.float32)             # (3, ..., S)
    pos_sel = jnp.take(pos, sec_id, axis=0)            # (half, ..., S) via axis-0 gather
    pos_sel = jnp.moveaxis(pos_sel, 0, -1)             # (..., S, half)
    ang = pos_sel * freqs
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(S: int, d: int):
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / (10_000.0 ** (dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)  # (S, d)


# ------------------------------------------------------------- attention ----

def softcap(logits, cap: float):
    if cap and cap > 0.0:
        return cap * jnp.tanh(logits / cap)
    return logits


def _attn_chunk(q_blk, k, v, mask_blk, scale, cap):
    """One query block of attention. q_blk: (B,Hkv,G,Cq,hd); k/v: (B,Hkv,S,hd);
    mask_blk: broadcastable to (B,1,1,Cq,S) boolean (True = keep).

    Perf knob attn_probs_dtype=bfloat16 keeps the row-max/sum reductions
    in fp32 but stores the (Cq,S) logits/probs tiles in bf16 — halves the
    dominant HBM-traffic term of the jnp prefill path."""
    from repro.common.perf import get_flags
    pdt = jnp.dtype(get_flags().attn_probs_dtype)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", q_blk.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    logits = softcap(logits, cap)
    logits = jnp.where(mask_blk[:, :, None, :, :], logits, -1e30)
    if pdt == jnp.bfloat16:
        m = jnp.max(logits, axis=-1, keepdims=True)
        p = jnp.exp((logits - m)).astype(pdt)
        denom = jnp.sum(p.astype(jnp.float32), axis=-1, keepdims=True)
        out = jnp.einsum("bhgqk,bhkd->bhgqd", p,
                         v.astype(pdt)).astype(jnp.float32) / denom
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhgqk,bhkd->bhgqd", probs,
                         v.astype(jnp.float32))
    return out.astype(q_blk.dtype)


def attention(q, k, v, *, causal: bool, window: int = 0, cap: float = 0.0,
              scale: float = 0.0, q_offset=0, kv_len=None,
              chunk: int = 0, backend=None):
    """Multi-query attention with a pluggable kernel backend.

    q: (B, Hq, Sq, hd); k, v: (B, Hkv, Sk, hd). GQA via reshape.
    window > 0 applies a sliding-window causal band (i-j < window).
    q_offset: absolute position of q[0] (for decode / chunked prefill).
    kv_len: valid kv entries (scalar or (B,), for cache decode); None = Sk.

    backend: kernel backend name (None = the PerfFlags default). With a
    non-reference backend and no active mesh, prefill/extend run the
    flash_prefill kernel and cache decode runs flash_decode; otherwise
    this falls through to the pure-jnp path below — memory-bounded
    (chunked over the query axis with a lax.scan to bound the logits
    temp) and GSPMD-friendly.
    """
    from repro.common.perf import get_flags
    from repro.kernels import backend as KB
    flags = get_flags()

    be = KB.get_backend(backend)
    if be.name != "reference" and KB.mesh_local():
        if kv_len is not None and q.shape[2] == 1 and not window:
            # single-token decode against a (partially) filled cache
            out = be.decode_attention(q[:, :, 0], k, v, kv_len, cap=cap,
                                      scale=scale)
            return out[:, :, None]
        if kv_len is None:
            # prefill / train / chunked-prefill extend (q_offset > 0)
            return be.attention(q, k, v, causal=causal, window=window,
                                cap=cap, scale=scale, q_offset=q_offset)
        # remaining shapes (multi-token vs kv_len'd cache) use the jnp path
    chunk = chunk or flags.attn_chunk
    kv_local = True   # no mesh -> KV trivially chip-local
    if flags.attn_constraint == "auto" and q.shape[2] > 1:
        # Prefill/train only: decode (Sq=1) attends over the live KV cache,
        # whose seq-sharded layout (decode_cache_seq) must not be overridden.
        from repro.distributed.annotate import constrain_attn
        q, k, v, kv_local = constrain_attn(q, k, v)
    else:
        from repro.distributed.annotate import _mesh
        kv_local = _mesh() is None
    B, Hq, Sq, hd = q.shape
    Hkv = k.shape[1]
    Sk = k.shape[2]
    G = Hq // Hkv
    scale = scale if scale else 1.0 / math.sqrt(hd)
    qg = q.reshape(B, Hkv, G, Sq, hd)

    kv_pos = jnp.arange(Sk)
    if kv_len is None:
        valid = jnp.ones((1, Sk), bool)                           # (1|B, Sk)
    else:
        kvl = jnp.asarray(kv_len)
        kvl = kvl[None] if kvl.ndim == 0 else kvl                 # (1,)|(B,)
        valid = kv_pos[None, :] < kvl[:, None]

    def mask_for(q_pos):
        # q_pos: (Cq,) absolute positions -> (1|B, Cq, Sk)
        m = valid[:, None, :]
        if causal:
            m = m & (kv_pos[None, None, :] <= q_pos[None, :, None])
        if window and window > 0:
            m = m & (q_pos[None, :, None] - kv_pos[None, None, :] < window)
        return m

    if Sq <= chunk:
        q_pos = q_offset + jnp.arange(Sq)
        m = mask_for(q_pos)[:, None]                              # (1|B,1,Sq,Sk)
        out = _attn_chunk(qg, k, v, jnp.broadcast_to(m, (B, Hkv, Sq, Sk)),
                          scale, cap)
        return out.reshape(B, Hq, Sq, hd)

    assert Sq % chunk == 0, (Sq, chunk)
    n_blk = Sq // chunk
    qb = qg.reshape(B, Hkv, G, n_blk, chunk, hd).transpose(3, 0, 1, 2, 4, 5)

    chunk_fn = lambda qi, kk, vv, m: _attn_chunk(qi, kk, vv, m, scale, cap)
    if flags.attn_chunk_remat == "on":
        # Don't save the stacked per-chunk (B,H,Cq,Sk) probs for backward —
        # recompute them; bounds the train-time temp to one chunk's logits.
        chunk_fn = jax.checkpoint(chunk_fn)

    # Sliding-window band slicing: a q-chunk starting at absolute position
    # p attends to kv positions in [p+chunk-1-window+1, p+chunk-1], so a
    # static-width (window+chunk) K/V band covers it; masking handles the
    # ragged edges. Only sound when q positions are contiguous from
    # q_offset (prefill/train), which is the only way this path is called.
    W_eff = min(Sk, window + chunk) if window and window > 0 else 0
    slice_kv = (flags.attn_window_slice == "on" and W_eff
                and W_eff < Sk and causal and kv_len is None
                and isinstance(q_offset, int) and kv_local)
    # kv_local guard: dynamic-slicing a *seq-sharded* KV makes GSPMD
    # rematerialize (EXPERIMENTS.md §Perf gemma2 iteration 3).

    def body(_, inp):
        i, qi = inp
        q_pos = q_offset + i * chunk + jnp.arange(chunk)
        if slice_kv:
            start = jnp.clip(q_offset + (i + 1) * chunk - W_eff, 0,
                             Sk - W_eff)
            kk = jax.lax.dynamic_slice_in_dim(k, start, W_eff, axis=2)
            vv = jax.lax.dynamic_slice_in_dim(v, start, W_eff, axis=2)
            kv_p = start + jnp.arange(W_eff)
            m = (kv_p[None, None, :] <= q_pos[None, :, None]) \
                & (q_pos[None, :, None] - kv_p[None, None, :] < window)
            m = jnp.broadcast_to(m[:, None], (B, Hkv, chunk, W_eff))
            return None, chunk_fn(qi, kk, vv, m)
        m = jnp.broadcast_to(mask_for(q_pos)[:, None], (B, Hkv, chunk, Sk))
        return None, chunk_fn(qi, k, v, m)

    _, ob = jax.lax.scan(body, None, (jnp.arange(n_blk), qb))
    out = ob.transpose(1, 2, 3, 0, 4, 5).reshape(B, Hq, Sq, hd)
    if flags.attn_constraint == "auto":
        from repro.distributed.annotate import constrain_attn_out
        out = constrain_attn_out(out, Hkv)
    return out


# --------------------------------------------------------- attn projections --

def attn_init(rng, cfg: ModelConfig, cross: bool = False, dtype=jnp.bfloat16):
    ks = jax.random.split(rng, 8)
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    p = {
        "wq": dense_init(ks[0], (d, qd), dtype=dtype),
        "wk": dense_init(ks[1], (d, kvd), dtype=dtype),
        "wv": dense_init(ks[2], (d, kvd), dtype=dtype),
        "wo": dense_init(ks[3], (qd, d), dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((qd,), dtype)
        p["bk"] = jnp.zeros((kvd,), dtype)
        p["bv"] = jnp.zeros((kvd,), dtype)
    return p


def qkv_proj(p, x, cfg: ModelConfig):
    """x: (B,S,d) -> q (B,Hq,S,hd), k,v (B,Hkv,S,hd) (pre-RoPE)."""
    B, S, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)
    k = k.reshape(B, S, cfg.n_kv_heads, cfg.d_head).transpose(0, 2, 1, 3)
    v = v.reshape(B, S, cfg.n_kv_heads, cfg.d_head).transpose(0, 2, 1, 3)
    return q, k, v


def out_proj(p, attn_out):
    """attn_out: (B,H,S,hd) -> (B,S,d)."""
    B, H, S, hd = attn_out.shape
    y = attn_out.transpose(0, 2, 1, 3).reshape(B, S, H * hd)
    return y @ p["wo"]


# -------------------------------------------------------------------- mlp ----

def mlp_init(rng, d: int, d_ff: int, act: str, dtype=jnp.bfloat16):
    ks = jax.random.split(rng, 3)
    if act.endswith("_glu"):
        return {"w_gate": dense_init(ks[0], (d, d_ff), dtype=dtype),
                "w_up": dense_init(ks[1], (d, d_ff), dtype=dtype),
                "w_down": dense_init(ks[2], (d_ff, d), dtype=dtype)}
    return {"w_up": dense_init(ks[0], (d, d_ff), dtype=dtype),
            "w_down": dense_init(ks[1], (d_ff, d), dtype=dtype)}


def mlp(p, x, act: str):
    if act == "silu_glu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    elif act == "gelu_glu":
        h = jax.nn.gelu(x @ p["w_gate"], approximate=True) * (x @ p["w_up"])
    elif act == "gelu":
        h = jax.nn.gelu(x @ p["w_up"], approximate=True)
    else:
        raise ValueError(act)
    return h @ p["w_down"]
