"""Quickstart: GeckOpt in ~60 lines.

Builds the synthetic GeoLLM-Engine platform, runs one task with the full
tool catalog and once with intent-gating, and prints the token ledgers —
the paper's Figure-1 story on a single query.

  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.agent import Agent
from repro.core.gate import IntentGate, ScriptedIntentClassifier
from repro.core.intents import build_intent_map
from repro.core.planner import PlannerConfig
from repro.core.tools import DEFAULT_REGISTRY
from repro.env.tasks import make_benchmark
from repro.env.world import build_world


def main():
    world = build_world(seed=0)
    tasks = make_benchmark(world, n_tasks=16)
    task = tasks[0]      # "Plot <sensor> images around <city> ..."
    print(f"Task: {task.query}\n")

    # offline phase: mine the intent -> API-library map from a task corpus
    intent_map = build_intent_map(tasks, DEFAULT_REGISTRY)
    print("Mined intent map (paper Table 1):")
    for intent, libs in sorted(intent_map.intent_to_libs.items()):
        print(f"  {intent:22s} -> {', '.join(libs)}")

    cfg = PlannerConfig(mode="react", few_shot=False)

    # 1) baseline: full 58-tool catalog in every planner prompt
    base_agent = Agent(DEFAULT_REGISTRY, world, cfg, gate=None, seed=0)
    r0 = base_agent.run_task(task)

    # 2) GeckOpt: one cheap intent call gates the catalog first
    gate = IntentGate(intent_map,
                      ScriptedIntentClassifier(0.97,
                                               np.random.default_rng(0)),
                      DEFAULT_REGISTRY.libraries())
    gk_agent = Agent(DEFAULT_REGISTRY, world, cfg, gate=gate, seed=0)
    r1 = gk_agent.run_task(task)

    print(f"\n{'':24s}{'full catalog':>14s}{'+GeckOpt':>12s}")
    print(f"{'intent':24s}{'—':>14s}{r1.intent_predicted:>12s}")
    for key in ("total_tokens", "plan_steps", "requests"):
        a = r0.ledger.summary()[key]
        b = r1.ledger.summary()[key]
        print(f"{key:24s}{a:>14,}{b:>12,}")
    print(f"{'tools executed':24s}{len(r0.executed_tools):>14}"
          f"{len(r1.executed_tools):>12}")
    red = 1 - r1.ledger.total_tokens / r0.ledger.total_tokens
    print(f"\ntoken reduction: {100 * red:.1f}%  "
          f"(paper: up to 24.6% across the 5k-task benchmark)")


if __name__ == "__main__":
    main()
