"""End-to-end driver (deliverable b): serve the Copilot platform.

Trains nothing — loads the planner-proxy LM, serves it with the batched
inference engine, and drives the full agent loop for a stream of user
queries with GeckOpt gating on/off, reporting tokens AND engine compute.

  PYTHONPATH=src python examples/serve_copilot.py [--requests 12]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core.agent import Agent
from repro.core.gate import IntentGate, ScriptedIntentClassifier
from repro.core.intents import build_intent_map
from repro.core.planner import PlannerConfig
from repro.core.tools import DEFAULT_REGISTRY
from repro.env.evaluator import evaluate
from repro.env.tasks import make_benchmark
from repro.env.world import build_world
from repro.models.model import count_params_analytic, init_params
from repro.serving.engine import InferenceEngine
from repro.serving.sampling import SamplerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    args = ap.parse_args()

    # --- the serving fleet: our own engine hosting the planner LM --------
    cfg = get_smoke_config("planner-proxy-100m")
    params = init_params(jax.random.PRNGKey(0), cfg)
    engine = InferenceEngine(cfg, params, max_batch=4, cache_len=256)
    n_params = count_params_analytic(cfg)
    print(f"planner engine up: {n_params/1e6:.1f}M params, 4 slots")

    # --- the platform ------------------------------------------------------
    world = build_world(0)
    tasks = make_benchmark(world, args.requests)
    imap = build_intent_map(make_benchmark(world, 64), DEFAULT_REGISTRY)
    gate = IntentGate(imap, ScriptedIntentClassifier(
        0.97, np.random.default_rng(0)), DEFAULT_REGISTRY.libraries())
    pcfg = PlannerConfig(mode="react", few_shot=False)

    for label, g in (("full-catalog", None), ("geckopt", gate)):
        agent = Agent(DEFAULT_REGISTRY, world, pcfg, gate=g, seed=0)
        rep = evaluate(agent, tasks, label)
        # every planner token the agent consumed becomes engine prefill
        # work: 2*N flops/token — the paper's cloud-cost link
        flops = 2 * n_params * rep.tokens_per_task
        print(f"{label:14s} success={100*rep.success_rate:5.1f}% "
              f"tokens/task={rep.tokens_per_task/1000:6.2f}k "
              f"steps={rep.steps_per_task:.2f} "
              f"-> {flops:.2e} planner FLOPs/task")

    # --- batched engine serving of the actual gate prompts ----------------
    t0 = time.time()
    for t in tasks:
        engine.add_request("classify intent: " + t.query,
                           max_new_tokens=4,
                           sampler=SamplerConfig(temperature=0.0))
    done = engine.run_until_done()
    dt = time.time() - t0
    st = engine.throughput_stats()
    print(f"\ngate traffic served by the engine: {len(done)} requests in "
          f"{dt:.2f}s ({st['tokens_generated']/max(dt,1e-9):.1f} tok/s, "
          f"continuous batching over 4 slots)")


if __name__ == "__main__":
    main()
