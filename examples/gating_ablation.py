"""Ablation: how the paper's trade-off moves with gate quality and
intent-map granularity.

Sweeps (a) classifier accuracy, (b) coverage quantile of the offline
mining phase (narrower vs safer library sets), and reports token
reduction vs success delta — the operating curve behind the paper's
"negligible performance degradation within 1%" claim.

  PYTHONPATH=src python examples/gating_ablation.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.agent import Agent
from repro.core.gate import IntentGate, ScriptedIntentClassifier
from repro.core.intents import build_intent_map
from repro.core.planner import PlannerConfig
from repro.core.tools import DEFAULT_REGISTRY
from repro.env.evaluator import evaluate
from repro.env.tasks import make_benchmark
from repro.env.world import build_world


def main():
    world = build_world(0)
    tasks = make_benchmark(world, 96)
    corpus = make_benchmark(world, 256, seed=3)
    cfg = PlannerConfig(mode="cot", few_shot=False)
    base = evaluate(Agent(DEFAULT_REGISTRY, world, cfg, gate=None,
                          seed=0), tasks, "base")
    print(f"baseline: {base.tokens_per_task/1000:.2f}k tokens/task, "
          f"success {100*base.success_rate:.1f}%\n")

    print("=== sweep 1: gate accuracy (coverage_q=0.98) ===")
    imap = build_intent_map(corpus, DEFAULT_REGISTRY, coverage_q=0.98)
    for acc in (1.0, 0.97, 0.9, 0.75, 0.5):
        gate = IntentGate(imap, ScriptedIntentClassifier(
            acc, np.random.default_rng(0)), DEFAULT_REGISTRY.libraries())
        r = evaluate(Agent(DEFAULT_REGISTRY, world, cfg, gate=gate,
                           seed=0), tasks, f"acc{acc}")
        red = 1 - r.tokens_per_task / base.tokens_per_task
        print(f"  acc={acc:4.2f}: -{100*red:5.1f}% tokens, success "
              f"{100*(r.success_rate-base.success_rate):+5.1f}pp, "
              f"fallback {100*r.fallback_rate:4.1f}%")

    print("\n=== sweep 2: offline-mining coverage quantile ===")
    for q in (0.999, 0.98, 0.9, 0.75):
        imap = build_intent_map(corpus, DEFAULT_REGISTRY, coverage_q=q)
        n_libs = np.mean([len(v) for v in imap.intent_to_libs.values()])
        gate = IntentGate(imap, ScriptedIntentClassifier(
            0.97, np.random.default_rng(0)), DEFAULT_REGISTRY.libraries())
        r = evaluate(Agent(DEFAULT_REGISTRY, world, cfg, gate=gate,
                           seed=0), tasks, f"q{q}")
        red = 1 - r.tokens_per_task / base.tokens_per_task
        print(f"  q={q:5.3f} (avg {n_libs:.1f} libs/intent): "
              f"-{100*red:5.1f}% tokens, success "
              f"{100*(r.success_rate-base.success_rate):+5.1f}pp, "
              f"fallback {100*r.fallback_rate:4.1f}%")


if __name__ == "__main__":
    main()
