"""End-to-end demo: the concurrent GeckOpt serving pipeline.

Composes every layer of the batched serving story:

  * a ``BatchedNeuralIntentClassifier`` gates each admission wave in ONE
    jitted (Q*8, L) forward pass of the planner-proxy LM;
  * ``GeckOptPipeline`` runs N Copilot sessions through gate → plan →
    execute concurrently (round-robin planner steps);
  * an ``InferenceEngine`` serves each session's first planner turn with
    per-intent prompt-prefix caching — sessions gated to the same intent
    reuse one cached prefill of the gated system prompt + catalog.

  PYTHONPATH=src python examples/serve_pipeline.py [--requests 12]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core.agent import Agent
from repro.core.catalog import (build_catalog, catalog_intent_libraries,
                                catalog_intent_map)
from repro.core.gate import IntentGate
from repro.core.intents import INTENTS, build_intent_map
from repro.core.planner import PlannerConfig
from repro.core.retriever import ToolRetriever
from repro.core.tools import DEFAULT_REGISTRY
from repro.env.evaluator import evaluate_results
from repro.env.tasks import make_benchmark
from repro.env.world import build_world
from repro.models.model import count_params_analytic, init_params
from repro.serving.cluster import EngineCluster, ROUTER_POLICIES
from repro.serving.engine import InferenceEngine
from repro.serving.neural_planner import BatchedNeuralIntentClassifier
from repro.serving.pipeline import GeckOptPipeline, PipelineConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--concurrency", type=int, default=8)
    ap.add_argument("--backend", default=None,
                    choices=("reference", "pallas"),
                    help="kernel backend for the engine's jitted steps")
    ap.add_argument("--replicas", type=int, default=1,
                    help="serve planner turns on an EngineCluster of N "
                         "replicas instead of one engine")
    ap.add_argument("--router", default="intent_affinity",
                    choices=ROUTER_POLICIES,
                    help="cluster routing policy (with --replicas > 1)")
    ap.add_argument("--kv-mode", default="dense",
                    choices=("dense", "paged"),
                    help="KV-cache manager for the engine(s): dense "
                         "slabs or the paged pool with CoW prefix "
                         "sharing")
    ap.add_argument("--kv-blocks", type=int, default=None,
                    help="paged: physical KV blocks per engine "
                         "(default: the dense budget)")
    ap.add_argument("--block-size", type=int, default=None,
                    help="paged: tokens per KV block (default: 16)")
    ap.add_argument("--spec-decode", action="store_true",
                    help="speculative decoding for planner turns: draft "
                         "--draft-k tokens per slot, verify in one "
                         "target forward (tokens stay bitwise "
                         "identical; the draft shares the target's "
                         "weights here)")
    ap.add_argument("--draft-k", type=int, default=4,
                    help="draft tokens per speculative round (>= 1)")
    ap.add_argument("--prefill-budget", type=int, default=None,
                    help="chunked prefill: max prompt tokens per engine "
                         "step, interleaved with decode (stall-free "
                         "scheduling; default: monolithic prefill). "
                         "Useful here — planner prompts carry ~2.5k-"
                         "token intent catalogs")
    ap.add_argument("--admission", default="fifo",
                    choices=("fifo", "slack"),
                    help="admission-queue order: arrival or earliest "
                         "SLA deadline first")
    ap.add_argument("--catalog-size", type=int, default=None,
                    help="serve a generated tool catalog of N tools "
                         "(core/catalog.py; default: the base "
                         "registry)")
    ap.add_argument("--retriever-k", type=int, default=None,
                    help="expose only the retrieved top-k toolset per "
                         "request (core/retriever.py) instead of the "
                         "gated library catalog; sessions retrieving "
                         "the same toolset share one engine prefix")
    ap.add_argument("--trace-out", default="",
                    help="write the unified pipeline+engine trace here "
                         "(.jsonl = record-per-line, anything else = "
                         "Chrome trace-event JSON for Perfetto)")
    args = ap.parse_args()
    if args.spec_decode and args.draft_k < 1:
        ap.error(f"--spec-decode needs --draft-k >= 1, "
                 f"got {args.draft_k}")
    if args.prefill_budget is not None and args.prefill_budget < 1:
        ap.error(f"--prefill-budget must be >= 1, "
                 f"got {args.prefill_budget}")
    if args.catalog_size is not None and args.catalog_size < 1:
        ap.error(f"--catalog-size must be >= 1, got {args.catalog_size}")
    if args.retriever_k is not None and args.retriever_k < 1:
        ap.error(f"--retriever-k must be >= 1, got {args.retriever_k}")

    # --- the serving fleet: engine(s) + one batched gate model -----------
    cfg = get_smoke_config("planner-proxy-100m")
    params = init_params(jax.random.PRNGKey(0), cfg)
    from repro.serving.specdec import SpecConfig
    spec = (SpecConfig(draft_cfg=cfg, draft_params=params,
                       k=args.draft_k)
            if args.spec_decode else None)
    # one tracer spans the whole stack: gate/plan/execute waves land on
    # the "pipeline" track, engine lifecycle events on per-slot tracks
    from repro.obs import Tracer
    tracer = Tracer() if args.trace_out else None
    # cache_len must hold the longest per-intent planner prefix (~2.5k
    # tokens of system prompt + catalog) plus the turn suffix; generated
    # catalogs serialize wider gated-library subsets, so give them room
    cache_len = 8192 if (args.catalog_size or 0) > 48 else 4096
    if args.replicas > 1:
        engine = EngineCluster(cfg, params, args.replicas,
                               router=args.router, max_batch=4,
                               cache_len=cache_len, backend=args.backend,
                               kv_mode=args.kv_mode,
                               kv_blocks=args.kv_blocks,
                               block_size=args.block_size,
                               spec_decode=spec,
                               prefill_budget=args.prefill_budget,
                               admission=args.admission,
                               tracer=tracer)
    else:
        engine = InferenceEngine(cfg, params, max_batch=4,
                                 cache_len=cache_len, backend=args.backend,
                                 kv_mode=args.kv_mode,
                                 kv_blocks=args.kv_blocks,
                                 block_size=args.block_size,
                                 spec_decode=spec,
                                 prefill_budget=args.prefill_budget,
                                 admission=args.admission,
                                 tracer=tracer)
    classifier = BatchedNeuralIntentClassifier(cfg, params)
    print(f"planner engine up: {count_params_analytic(cfg)/1e6:.1f}M "
          f"params, {args.replicas} replica(s) x 4 slots; "
          f"batched intent gate ready")

    # --- the platform ----------------------------------------------------
    world = build_world(0)
    tasks = make_benchmark(world, args.requests)
    if args.catalog_size is not None:
        registry = build_catalog(args.catalog_size, seed=0)
        imap = catalog_intent_map(registry)
    else:
        registry = DEFAULT_REGISTRY
        imap = build_intent_map(make_benchmark(world, 64), registry)
    gate = IntentGate(imap, classifier, registry.libraries())
    retriever = None
    exposure = "gated"
    if args.retriever_k is not None:
        retriever = ToolRetriever(registry,
                                  catalog_intent_libraries(registry),
                                  k=args.retriever_k)
        exposure = "retrieved"
        print(f"toolset retrieval on: top-{args.retriever_k} of "
              f"{len(registry.tools)} tools exposed per request")
    agent = Agent(registry, world,
                  PlannerConfig(mode="react", few_shot=False),
                  gate=gate, seed=0, retriever=retriever,
                  exposure=exposure)

    # --- run everything through the concurrent pipeline ------------------
    pipe = GeckOptPipeline(
        agent, PipelineConfig(max_concurrent=args.concurrency),
        engine=engine, tracer=tracer)
    t0 = time.time()
    results = pipe.run(tasks)
    dt = time.time() - t0
    rep = evaluate_results(results, "pipeline")

    ps = pipe.stats.summary()
    es = engine.throughput_stats()
    print(f"\n{len(results)} sessions in {dt:.2f}s "
          f"({len(results)/max(dt,1e-9):.2f} tasks/s, "
          f"{args.concurrency} concurrent)")
    mgb = ps["mean_gate_batch"]          # None when no wave ran
    print(f"gate:    {ps['gate_batches']} batched calls, mean wave "
          f"{'n/a' if mgb is None else f'{mgb:.1f}'} queries "
          f"(vs {len(INTENTS)*len(results)} B=1 forwards sequentially)")
    print(f"engine:  {ps['engine_turns']} planner turns over "
          f"{len(engine.prefixes)} intent prefixes — "
          f"{es['prefix_hits']} prefix hits, "
          f"{es['prefix_tokens_saved']} prefill tokens saved, "
          f"{es['tokens_generated']} tokens decoded")
    print(f"kv[{es['kv_mode']}]: peak {es['kv_bytes_peak'] / 2**20:.1f} "
          f"MiB of {es['kv_bytes_allocated'] / 2**20:.1f} MiB"
          + (f" | shared-block frac {es['kv_shared_frac']:.2f}, "
             f"{es['preemptions']} preemptions"
             if es["kv_mode"] == "paged" else ""))
    if args.retriever_k is not None:
        print(f"retrieve: {ps['retrievals']} toolsets retrieved "
              f"(top-{args.retriever_k}), "
              f"{ps['retrieval_widens']} miss-and-widen escalations")
    if args.spec_decode:
        print(f"spec-decode[k={args.draft_k}]: "
              f"{es['tokens_per_step']:.2f} tokens/target-forward, "
              f"accept rate {es['spec_accept_rate']:.2f} over "
              f"{es['spec_rounds']} rounds")
    if args.replicas > 1:
        for r in es["per_replica"]:
            print(f"  replica {r['replica']}: {r['admissions']} turns, "
                  f"{r['prefix_hits']} prefix hits, "
                  f"{r['tokens_generated']} tokens")
    print(f"quality: success={100*rep.success_rate:.1f}% "
          f"tokens/task={rep.tokens_per_task/1000:.2f}k "
          f"steps={rep.steps_per_task:.2f} "
          f"fallback={100*rep.fallback_rate:.1f}%")
    print("(gate params are random-init here, so fallback is high — "
          "examples/train_planner.py fine-tunes the proxy into an "
          "accurate gate)")
    if tracer is not None:
        from repro.obs.export import write_trace
        write_trace(tracer, args.trace_out)
        print(f"trace: {len(tracer.records)} records -> "
              f"{args.trace_out}")


if __name__ == "__main__":
    main()
